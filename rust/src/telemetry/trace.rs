//! The flight recorder: an opt-in stream of Chrome-trace-format span
//! events (one JSON object per line) for profiling the plan executor's
//! compile → round → wave → pool schedule.
//!
//! Enabled by `ASTRA_TRACE=<path>` (read once per process via
//! [`init_from_env`]) or programmatically / by `astra … --trace <path>`
//! through [`enable`]. When disabled — the default — every [`emit`] call
//! is a single relaxed atomic load and an immediate return; that *is* the
//! hot-path overhead contract, pinned by the bench `telemetry_overhead`
//! leg.
//!
//! Each line is a complete ("ph":"X") event: `name`, `cat`, `ts`/`dur` in
//! microseconds, and an `args` object carrying executor context (plan id,
//! round, wave, pool, strategies scored, memo hit-rate). Timestamps count
//! from [`super::process_epoch`] — the same epoch the log prefix uses —
//! and are computed *under the sink lock*, so `ts` is nondecreasing in
//! file order even with concurrent searches (`astra trace-check` and the
//! ci.sh smoke lane assert exactly that). Load a trace with Perfetto /
//! `chrome://tracing` after wrapping the lines in a JSON array, or grep
//! it as-is.
//!
//! Tracing never touches results: reports are byte-identical with the
//! recorder on or off (pinned in `determinism.rs`), and a write failure
//! disables the recorder rather than failing the search.

use crate::json::{self, Value};
use std::fs::File;
use std::io::{LineWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once};

/// Fast-path switch: [`emit`] bails on one relaxed load when off.
static TRACE_ON: AtomicBool = AtomicBool::new(false);
/// The open sink. `LineWriter` flushes per event line, so the file is
/// complete even though process-exit never drops statics.
static SINK: Mutex<Option<LineWriter<File>>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

/// Is the recorder on? Call sites guard event *construction* behind this
/// so the disabled path never formats or allocates.
#[inline]
pub fn enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// One-shot `ASTRA_TRACE=<path>` pickup; idempotent, cheap after the
/// first call. A bad path warns and leaves the recorder off — tracing is
/// observability, never a reason to fail a search.
pub fn init_from_env() {
    ENV_INIT.call_once(|| {
        if let Ok(path) = std::env::var("ASTRA_TRACE") {
            if !path.is_empty() {
                if let Err(e) = enable(Path::new(&path)) {
                    crate::log_warn!("trace: ASTRA_TRACE={path} not usable: {e}");
                }
            }
        }
    });
}

/// Start streaming events to `path` (truncates any existing file).
pub fn enable(path: &Path) -> crate::Result<()> {
    let file = File::create(path)?;
    *SINK.lock().unwrap() = Some(LineWriter::new(file));
    TRACE_ON.store(true, Ordering::Relaxed);
    Ok(())
}

/// Stop recording and flush/close the sink.
pub fn disable() {
    TRACE_ON.store(false, Ordering::Relaxed);
    if let Some(mut sink) = SINK.lock().unwrap().take() {
        let _ = sink.flush();
    }
}

/// Write one complete span event (`ph:"X"`): `dur_secs` is the span
/// length, `args` the executor context. No-op when disabled. The `ts`
/// stamp is taken under the sink lock — see the module docs.
pub fn emit(name: &str, cat: &str, dur_secs: f64, args: Value) {
    if !enabled() {
        return;
    }
    let mut guard = SINK.lock().unwrap();
    let Some(sink) = guard.as_mut() else { return };
    let ts_us = super::process_epoch().elapsed().as_secs_f64() * 1e6;
    let event = Value::obj()
        .set("args", args)
        .set("cat", cat)
        .set("dur", dur_secs.max(0.0) * 1e6)
        .set("name", name)
        .set("ph", "X")
        .set("pid", 1u64)
        .set("tid", 0u64)
        .set("ts", ts_us);
    let line = json::to_string(&event);
    if writeln!(sink, "{line}").is_err() {
        // A dead sink (disk full, closed fd) must not sink the search.
        drop(guard);
        disable();
        crate::log_warn!("trace: write failed; recorder disabled");
        return;
    }
    drop(guard);
    crate::telemetry::counter_macro!("astra_trace_events_total").inc();
}

/// FNV-1a over a plan's canonical JSON — the stable `plan` id that ties
/// every span of one search together in a trace. Only computed when the
/// recorder is on.
pub fn plan_id(canonical_plan: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in canonical_plan.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Enable → emit → disable writes parseable JSONL with nondecreasing
    /// timestamps; runs serially inside one test since the recorder is
    /// process-global.
    #[test]
    fn recorder_roundtrip_monotonic_and_parseable() {
        let path = std::env::temp_dir().join(format!("astra_trace_test_{}.jsonl", std::process::id()));
        assert!(!enabled(), "recorder must default to off");
        emit("noop", "test", 0.0, Value::obj()); // disabled: must be a no-op
        enable(&path).unwrap();
        assert!(enabled());
        for i in 0..8u64 {
            emit("span", "test", 1e-4, Value::obj().set("i", i));
        }
        disable();
        assert!(!enabled());
        emit("after", "test", 0.0, Value::obj()); // off again: swallowed

        let text = std::fs::read_to_string(&path).unwrap();
        let mut last_ts = f64::NEG_INFINITY;
        let mut ours = 0usize;
        for line in text.lines() {
            let v = json::parse(line).unwrap();
            assert_eq!(v.opt_str("ph"), Some("X"));
            let ts = v.req_f64("ts").unwrap();
            assert!(ts >= last_ts, "ts must be nondecreasing in file order");
            last_ts = ts;
            // Concurrent unit tests may run searches while the recorder is
            // on (it is process-global); count only this test's spans.
            if v.opt_str("cat") == Some("test") {
                assert_eq!(v.opt_str("name"), Some("span"));
                ours += 1;
            }
        }
        assert_eq!(ours, 8, "exactly the enabled-window test spans are on disk");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn plan_id_is_stable_and_input_sensitive() {
        assert_eq!(plan_id("abc"), plan_id("abc"));
        assert_ne!(plan_id("abc"), plan_id("abd"));
        assert_eq!(plan_id("").len(), 16);
    }
}
