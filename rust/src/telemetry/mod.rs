//! `astra::telemetry` — the unified observability substrate: one
//! process-global metrics registry plus an opt-in flight recorder
//! ([`trace`]). Zero external dependencies, like [`crate::logging`].
//!
//! ## Registry architecture
//!
//! One process-global [`Registry`] maps metric *names* to typed handles:
//!
//! * [`Counter`] — monotone `u64`, saturating on overflow (a counter that
//!   pegs at `u64::MAX` is more useful than one that wraps to a small lie);
//! * [`Gauge`] — settable `i64` level (queue depths, resident scopes,
//!   snapshot bytes);
//! * [`Histogram`] — fixed log₂-scale latency buckets: bucket `i` counts
//!   observations `≤ 2^(i-20)` seconds (`i = 0..40`, so ~0.95 µs up to
//!   ~6 days) plus one overflow bucket for `+∞`/NaN. Zero, negative and
//!   subnormal observations land in bucket 0; the bucket layout is fixed
//!   at compile time so dumps from different processes are mergeable.
//!
//! Handles are `Arc`s: subsystems resolve a name once (at construction —
//! [`register_core_metrics`] pre-registers the full well-known set so one
//! dump always shows the whole picture) and bump plain relaxed atomics on
//! the hot path. The global map lock is touched only at registration and
//! at dump time. The pre-existing per-instance counters (cache stats, memo
//! registries, persist counters) are *mirrored* into the registry, not
//! replaced: per-instance semantics stay exactly as before (tests and the
//! wire `stats` payload depend on them), while the registry accumulates
//! the process-wide totals behind one `{"cmd":"metrics"}` /
//! `astra stats --metrics-text` surface.
//!
//! ## Determinism contract
//!
//! Telemetry is observability, never results:
//!
//! * nothing in this module enters the request fingerprint
//!   ([`crate::service::fingerprint`]) or the canonical result view
//!   ([`crate::report::report_json`]);
//! * metric *values* are load-dependent (warmth, worker interleaving), so
//!   golden wire transcripts zero them exactly like the wall-time fields
//!   ([`crate::service::server::normalize_response_line`]);
//! * the flight recorder only ever writes to its own file — reports are
//!   byte-identical with tracing on or off (pinned by `determinism.rs`
//!   and the ci.sh trace smoke lane), and the disabled path is a single
//!   relaxed atomic load.
//!
//! ## Metric naming scheme
//!
//! Prometheus-style snake case, `astra_` prefix: counters end in
//! `_total`, histograms in `_seconds`, gauges are bare levels. The
//! well-known set:
//!
//! | metric | type | meaning |
//! |---|---|---|
//! | `astra_searches_total` | counter | searches that entered the pipeline |
//! | `astra_strategies_generated_total` | counter | raw candidates expanded |
//! | `astra_strategies_scored_total` | counter | candidates scored |
//! | `astra_cache_{hits,misses,insertions,evictions,expirations,oversize_rejects}_total` | counter | result-cache traffic |
//! | `astra_memo_{hits,misses}_total` | counter | shared-cost-memo traffic |
//! | `astra_persist_scopes_{spilled,restored,rejected,dropped}_total` | counter | warm-start scope movement |
//! | `astra_persist_cache_{spilled,restored}_total` | counter | warm-start cache-entry movement |
//! | `astra_trace_events_total` | counter | flight-recorder events written |
//! | `astra_requests_shed_total` | counter | requests refused by load shedding |
//! | `astra_requests_deadline_total` | counter | requests ended by their deadline |
//! | `astra_requests_panicked_total` | counter | request panics caught and isolated |
//! | `astra_faults_injected_total` | counter | failpoint firings ([`crate::resilience::failpoint`]) |
//! | `astra_audited_searches_total` | counter | searches that carried a decision audit |
//! | `astra_health_checks_total` | counter | `{"cmd":"health"}` / `astra health` probes answered |
//! | `astra_admission_queue_depth` | gauge | distinct requests in fan-out |
//! | `astra_memo_scopes` | gauge | live memo scopes |
//! | `astra_persist_snapshot_bytes` | gauge | last snapshot size on disk |
//! | `astra_search_e2e_seconds` | histogram | per-search end-to-end time |
//! | `astra_phase_{compile,speculate,expand_rules,mem_filter,score,hlo_pack}_seconds` | histogram | per-search phase times |
//! | `astra_request_{homogeneous,heterogeneous,cost,hetero_cost,frontier}_seconds` | histogram | served request latency per mode (the [`window`] health quantiles read these) |
//!
//! The set is *pinned*: [`core_metric_names`] returns exactly this table
//! and `rust/tests/metrics_names.rs` asserts it matches the golden README
//! table — rename or add a metric and both must move together.
//! Use the [`counter!`](crate::telemetry_counter)/[`gauge!`](crate::telemetry_gauge)/
//! [`histogram!`](crate::telemetry_histogram) macros for one-line call
//! sites: they cache the resolved handle in a per-call-site static, so the
//! registry lock is paid once per site, not per event.

pub mod trace;
pub mod window;

use crate::json::Value;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// The process start instant shared by log lines and trace timestamps
/// (the [`crate::logging`] `[   1.234s ...]` column and the flight
/// recorder's `ts` field count from the same epoch).
pub fn process_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotone counter. Saturates at `u64::MAX` instead of wrapping.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if n == 0 {
            return;
        }
        // fetch_add wraps; a saturating CAS keeps a pegged counter honest.
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(n);
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Settable level (queue depth, resident scopes, bytes on disk).
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Finite log₂-bucket boundary count; one overflow bucket follows.
const HIST_BUCKETS: usize = 40;
/// Lowest bucket upper bound: 2⁻²⁰ s ≈ 0.95 µs (each next bound doubles).
const HIST_MIN_BOUND: f64 = 1.0 / 1048576.0;

/// Upper bound (`le`) of finite bucket `i` in seconds.
pub(crate) fn bucket_bound(i: usize) -> f64 {
    let mut b = HIST_MIN_BOUND;
    for _ in 0..i {
        b *= 2.0;
    }
    b
}

/// Bucket index for one observation: `0` for anything `≤ 2⁻²⁰ s`
/// (including zero, negatives and subnormals), `HIST_BUCKETS` (overflow)
/// for `+∞`, NaN, and anything past the top bound.
fn bucket_index(v: f64) -> usize {
    if v.is_nan() {
        return HIST_BUCKETS;
    }
    let mut bound = HIST_MIN_BOUND;
    for i in 0..HIST_BUCKETS {
        if v <= bound {
            return i;
        }
        bound *= 2.0;
    }
    HIST_BUCKETS
}

/// Fixed log₂-scale latency histogram (see the module docs for the bucket
/// layout). The sum accumulates in nanoseconds so it stays a saturating
/// atomic like everything else; `+∞` observations peg it.
pub struct Histogram {
    buckets: Vec<AtomicU64>, // HIST_BUCKETS + 1 (overflow), non-cumulative
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..=HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one latency in seconds.
    pub fn observe(&self, secs: f64) {
        self.buckets[bucket_index(secs)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // Float-to-int casts saturate (NaN → 0), so ∞ pegs instead of UB.
        let ns = (secs.max(0.0) * 1e9) as u64;
        if ns > 0 {
            let mut cur = self.sum_nanos.load(Ordering::Relaxed);
            loop {
                let next = cur.saturating_add(ns);
                match self.sum_nanos.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(now) => cur = now,
                }
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Non-cumulative bucket counts, overflow last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// The process-global name → handle map. Locked only at registration and
/// dump time; handles bump lock-free atomics.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

/// Get-or-create the named counter. Registering a name that already holds
/// a different metric type returns a fresh detached handle (never panics
/// on the telemetry path) — don't do that.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut m = registry().metrics.lock().unwrap();
    match m
        .entry(name.to_string())
        .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
    {
        Metric::Counter(c) => c.clone(),
        _ => Arc::new(Counter::default()),
    }
}

/// Get-or-create the named gauge (see [`counter`] on type mismatches).
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut m = registry().metrics.lock().unwrap();
    match m.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
    {
        Metric::Gauge(g) => g.clone(),
        _ => Arc::new(Gauge::default()),
    }
}

/// Get-or-create the named histogram (see [`counter`] on type mismatches).
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut m = registry().metrics.lock().unwrap();
    match m
        .entry(name.to_string())
        .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::default())))
    {
        Metric::Histogram(h) => h.clone(),
        _ => Arc::new(Histogram::default()),
    }
}

/// How many metrics are registered.
pub fn metric_count() -> usize {
    registry().metrics.lock().unwrap().len()
}

/// The pinned well-known counter names (the module-doc table).
pub const CORE_COUNTERS: &[&str] = &[
    "astra_searches_total",
    "astra_strategies_generated_total",
    "astra_strategies_scored_total",
    "astra_cache_hits_total",
    "astra_cache_misses_total",
    "astra_cache_insertions_total",
    "astra_cache_evictions_total",
    "astra_cache_expirations_total",
    "astra_cache_oversize_rejects_total",
    "astra_memo_hits_total",
    "astra_memo_misses_total",
    "astra_persist_scopes_spilled_total",
    "astra_persist_scopes_restored_total",
    "astra_persist_scopes_rejected_total",
    "astra_persist_scopes_dropped_total",
    "astra_persist_cache_spilled_total",
    "astra_persist_cache_restored_total",
    "astra_trace_events_total",
    "astra_requests_shed_total",
    "astra_requests_deadline_total",
    "astra_requests_panicked_total",
    "astra_faults_injected_total",
    "astra_audited_searches_total",
    "astra_health_checks_total",
];

/// The pinned well-known gauge names.
pub const CORE_GAUGES: &[&str] =
    &["astra_admission_queue_depth", "astra_memo_scopes", "astra_persist_snapshot_bytes"];

/// The pinned well-known histogram names. The `astra_request_*_seconds`
/// family is one histogram per [`crate::strategy::GpuPoolMode`] variant —
/// the health window ([`window`]) reads its quantiles from these.
pub const CORE_HISTOGRAMS: &[&str] = &[
    "astra_search_e2e_seconds",
    "astra_phase_compile_seconds",
    "astra_phase_speculate_seconds",
    "astra_phase_expand_rules_seconds",
    "astra_phase_mem_filter_seconds",
    "astra_phase_score_seconds",
    "astra_phase_hlo_pack_seconds",
    "astra_request_homogeneous_seconds",
    "astra_request_heterogeneous_seconds",
    "astra_request_cost_seconds",
    "astra_request_hetero_cost_seconds",
    "astra_request_frontier_seconds",
];

/// Every pinned well-known metric name, counters → gauges → histograms.
/// The drift guard (`rust/tests/metrics_names.rs`) asserts this set is
/// exactly the golden README's metric table.
pub fn core_metric_names() -> Vec<&'static str> {
    CORE_COUNTERS
        .iter()
        .chain(CORE_GAUGES.iter())
        .chain(CORE_HISTOGRAMS.iter())
        .copied()
        .collect()
}

/// Pre-register the full well-known metric set (the module-doc table) so a
/// fresh process dumps the whole picture — zeros included — instead of
/// only the names whose code paths happened to run. Called from
/// [`crate::coordinator::ScoringCore::new`]; idempotent.
pub fn register_core_metrics() {
    for name in CORE_COUNTERS {
        let _ = counter(name);
    }
    for name in CORE_GAUGES {
        let _ = gauge(name);
    }
    for name in CORE_HISTOGRAMS {
        let _ = histogram(name);
    }
}

/// The registry as canonical JSON (sorted names, like every other wire
/// payload): `{"counters":{…},"gauges":{…},"histograms":{name:
/// {"buckets":{"b07":n,…,"inf":n},"count":N,"sum_secs":S}}}`. Histogram
/// buckets are non-cumulative, keyed `bNN` by bucket index (bound
/// `2^(NN-20)` s) with only non-zero buckets emitted; `"inf"` is the
/// overflow bucket.
pub fn registry_json() -> Value {
    let m = registry().metrics.lock().unwrap();
    let mut counters = Value::obj();
    let mut gauges = Value::obj();
    let mut histograms = Value::obj();
    for (name, metric) in m.iter() {
        match metric {
            Metric::Counter(c) => {
                counters = counters.set(name, c.get() as f64);
            }
            Metric::Gauge(g) => {
                gauges = gauges.set(name, g.get() as f64);
            }
            Metric::Histogram(h) => {
                let mut buckets = Value::obj();
                for (i, n) in h.bucket_counts().into_iter().enumerate() {
                    if n == 0 {
                        continue;
                    }
                    let key = if i == HIST_BUCKETS {
                        "inf".to_string()
                    } else {
                        format!("b{i:02}")
                    };
                    buckets = buckets.set(&key, n as f64);
                }
                histograms = histograms.set(
                    name,
                    Value::obj()
                        .set("buckets", buckets)
                        .set("count", h.count() as f64)
                        .set("sum_secs", h.sum_secs()),
                );
            }
        }
    }
    Value::obj()
        .set("counters", counters)
        .set("gauges", gauges)
        .set("histograms", histograms)
}

/// Prometheus-style text exposition of the registry (`astra stats
/// --metrics-text`). Histogram buckets are cumulative with `le` labels,
/// the conventional `_bucket`/`_sum`/`_count` triplet.
pub fn registry_text() -> String {
    let m = registry().metrics.lock().unwrap();
    let mut out = String::new();
    for (name, metric) in m.iter() {
        match metric {
            Metric::Counter(c) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
            }
            Metric::Gauge(g) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
            }
            Metric::Histogram(h) => {
                out.push_str(&format!("# TYPE {name} histogram\n"));
                let mut cumulative = 0u64;
                for (i, n) in h.bucket_counts().into_iter().enumerate() {
                    cumulative = cumulative.saturating_add(n);
                    if i == HIST_BUCKETS {
                        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cumulative}\n"));
                    } else if n > 0 {
                        // Elide empty finite buckets; +Inf always closes
                        // the series so the total stays visible.
                        out.push_str(&format!(
                            "{name}_bucket{{le=\"{}\"}} {cumulative}\n",
                            bucket_bound(i)
                        ));
                    }
                }
                out.push_str(&format!("{name}_sum {}\n", h.sum_secs()));
                out.push_str(&format!("{name}_count {}\n", h.count()));
            }
        }
    }
    out
}

/// One-line counter access with a per-call-site handle cache: the registry
/// lock is paid on the first hit only. `$name` should be a literal — the
/// cache keys on the call site, not the string.
#[macro_export]
macro_rules! telemetry_counter {
    ($name:expr) => {{
        static HANDLE: ::std::sync::OnceLock<::std::sync::Arc<$crate::telemetry::Counter>> =
            ::std::sync::OnceLock::new();
        ::std::sync::Arc::clone(HANDLE.get_or_init(|| $crate::telemetry::counter($name)))
    }};
}

/// [`telemetry_counter!`] for gauges.
#[macro_export]
macro_rules! telemetry_gauge {
    ($name:expr) => {{
        static HANDLE: ::std::sync::OnceLock<::std::sync::Arc<$crate::telemetry::Gauge>> =
            ::std::sync::OnceLock::new();
        ::std::sync::Arc::clone(HANDLE.get_or_init(|| $crate::telemetry::gauge($name)))
    }};
}

/// [`telemetry_counter!`] for histograms.
#[macro_export]
macro_rules! telemetry_histogram {
    ($name:expr) => {{
        static HANDLE: ::std::sync::OnceLock<::std::sync::Arc<$crate::telemetry::Histogram>> =
            ::std::sync::OnceLock::new();
        ::std::sync::Arc::clone(HANDLE.get_or_init(|| $crate::telemetry::histogram($name)))
    }};
}

// The `telemetry::counter!("…")` spelling: path-accessible aliases of the
// exported macros (macro and function namespaces are disjoint, so these
// coexist with the `fn counter`-style accessors above).
pub use crate::telemetry_counter as counter_macro;
pub use crate::telemetry_gauge as gauge_macro;
pub use crate::telemetry_histogram as histogram_macro;

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and tests share one process: every
    // test uses metric names no production code touches.

    #[test]
    fn counter_saturates_instead_of_wrapping() {
        let c = Counter::default();
        c.add(u64::MAX - 1);
        c.add(5);
        assert_eq!(c.get(), u64::MAX, "overflow must peg, not wrap");
        c.inc();
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn histogram_bucket_edges() {
        // Zero, negatives and subnormals land in bucket 0.
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        assert_eq!(bucket_index(f64::MIN_POSITIVE / 2.0), 0, "subnormal");
        assert_eq!(bucket_index(1e-320), 0, "subnormal");
        // Exact boundary is inclusive; just past it moves up one.
        assert_eq!(bucket_index(HIST_MIN_BOUND), 0);
        assert_eq!(bucket_index(HIST_MIN_BOUND * 1.0000001), 1);
        // Infinity, NaN and beyond-top-bound overflow.
        assert_eq!(bucket_index(f64::INFINITY), HIST_BUCKETS);
        assert_eq!(bucket_index(f64::NAN), HIST_BUCKETS);
        assert_eq!(bucket_index(1e300), HIST_BUCKETS);
        // A human-scale latency sits strictly inside the finite range.
        let i = bucket_index(1.27);
        assert!(i > 0 && i < HIST_BUCKETS, "1.27 s must be a finite bucket, got {i}");
        assert!(bucket_bound(i) >= 1.27 && bucket_bound(i.saturating_sub(1)) < 1.27);
    }

    #[test]
    fn histogram_observe_accounts_count_and_sum() {
        let h = Histogram::default();
        h.observe(0.0);
        h.observe(1e-320);
        h.observe(0.5);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 4);
        let counts = h.bucket_counts();
        assert_eq!(counts[0], 2, "zero + subnormal share bucket 0");
        assert_eq!(counts[HIST_BUCKETS], 1, "inf lands in overflow");
        assert_eq!(counts.iter().sum::<u64>(), 4);
        // ∞ pegs the sum; it must not wrap back down.
        assert!(h.sum_secs() >= 0.5);
    }

    #[test]
    fn registry_get_or_create_returns_shared_handles() {
        let a = counter("astra_test_registry_shared_total");
        let b = counter("astra_test_registry_shared_total");
        a.add(3);
        assert_eq!(b.get(), 3, "same name must resolve to the same counter");
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn macros_cache_and_resolve() {
        telemetry_counter!("astra_test_macro_total").add(2);
        assert_eq!(counter("astra_test_macro_total").get(), 2);
        telemetry_gauge!("astra_test_macro_gauge").set(-7);
        assert_eq!(gauge("astra_test_macro_gauge").get(), -7);
        telemetry_histogram!("astra_test_macro_seconds").observe(0.25);
        assert_eq!(histogram("astra_test_macro_seconds").count(), 1);
    }

    #[test]
    fn json_and_text_render_the_test_metrics() {
        counter("astra_test_render_total").add(9);
        histogram("astra_test_render_seconds").observe(0.125);
        let v = registry_json();
        assert_eq!(
            v.pointer("/counters/astra_test_render_total").and_then(Value::as_f64),
            Some(9.0)
        );
        let h = v.pointer("/histograms/astra_test_render_seconds").unwrap();
        assert_eq!(h.get("count").and_then(Value::as_f64), Some(1.0));
        let text = registry_text();
        assert!(text.contains("# TYPE astra_test_render_total counter"));
        assert!(text.contains("astra_test_render_total 9"));
        assert!(text.contains("astra_test_render_seconds_count 1"));
        assert!(text.contains("le=\"+Inf\"}} 1") || text.contains("le=\"+Inf\"} 1"));
    }
}
