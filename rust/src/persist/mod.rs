//! `astra::persist` — the versioned warm-start store.
//!
//! PR 3 made repeat traffic sublinear through the shared cost memo, but
//! every restart of `astra serve` threw that warmth away and paid the full
//! cold pass again. This module defines a durable on-disk contract for the
//! warm state: hot [`crate::cost::SharedCostMemo`] scopes (the
//! `StageKey → StageTime` and `SyncKey → (dp, opt, off)` tables) and,
//! optionally, the service's sharded result cache, spill to a
//! line-delimited JSON snapshot and restore on startup — so a restarted
//! service skips the cold pass entirely.
//!
//! ## File format (`astra_warm` v1)
//!
//! One JSON object per line, written through the in-tree [`crate::json`]
//! (no new dependencies):
//!
//! ```text
//! {"astra_warm":1}                                     file header
//! {"scope":{"kind":"memo","format":1,"key":"<hex16>",  scope header
//!           "catalog":"<hex16>","eta":"analytic",
//!           "consts":"<hex16>","book":"<hex16>",
//!           "stage_rows":N,"sync_rows":M}}
//! {"k":[13 ints],"t":"stage","v":["<hex16>",×3]}       N stage rows
//! {"k":[10 ints],"t":"sync","v":["<hex16>",×3]}        M sync rows
//! {"end":"<hex16 key>","rows":N+M,"sum":"<hex16>"}     scope footer
//! {"scope":{"kind":"cache","format":1,...,"entries":K}}
//! {"fp":"<hex16>","report":{…},"t":"report"}           K cache rows
//! {"end":"cache","rows":K,"sum":"<hex16>"}
//! ```
//!
//! Every `f64` payload is serialized as the 16-hex-digit form of its IEEE
//! bit pattern, so a restored value is **bit-identical** to the spilled one
//! — a restored-memo search must reproduce a cold search byte-for-byte,
//! and shortest-round-trip decimal would be one `ulp` of risk for zero
//! benefit. Scope footers carry an FNV-1a checksum over the decoded rows;
//! a flipped bit inside an otherwise well-formed row is caught there.
//!
//! ## Integrity: never trust-and-load
//!
//! A snapshot is only as good as the engine it was spilled from. Each
//! scope header pins everything the memo'd values depend on besides the
//! key itself (the scope/key split documented atop [`crate::cost`]):
//!
//! | header field | pins                                  | on mismatch |
//! |--------------|---------------------------------------|-------------|
//! | `format`     | row encoding version                  | skip scope  |
//! | `key`        | `model_scope_key` (the model spec)    | n/a (scopes coexist) |
//! | `catalog`    | [`catalog_digest`]: every `GpuSpec` field + topology | skip scope |
//! | `eta`        | [`eta_identity`]: analytic vs forests (+ forest digest) | skip scope |
//! | `consts`     | [`consts_digest`]: the `CostConsts` overlap/host rates | skip scope |
//! | `book`       | [`book_digest`]: the full price card + spot/ToD state | skip scope |
//!
//! Mismatching, corrupt, truncated or partially written scopes are
//! *skipped* — counted in [`RestoreStats::scopes_rejected`], never an
//! error and never a wrong answer; the engine just starts cold for that
//! scope. The only hard failure [`read_warm`] has is none at all: it
//! always returns, with whatever subset of the file validated.
//!
//! Cache entries restore behind the same digest gate. Their fingerprints
//! additionally encode the full request+config key, so entries spilled
//! under a config that later changed are simply never hit again and age
//! out by LRU. Cache TTLs restart on restore (the snapshot stores no wall
//! clock).
//!
//! ## Who calls what
//!
//! * [`crate::coordinator::ScoringCore::save_warm`] / `load_warm` — memo
//!   scopes only (CLI `astra warm save|load`, `astra search --warm-*`).
//!   `save_warm_within` / `export_warm_within` enforce an optional
//!   `max_snapshot_bytes` budget: scope sections are sized individually and
//!   least-recently-used scopes are dropped first (counted in the
//!   `persist_scopes_dropped` stats counter) so a bounded snapshot keeps
//!   the hottest warmth.
//! * [`crate::service::SearchService::spill_warm`] / `restore_warm` — memo
//!   scopes plus the result cache (`astra serve --warm-dir`, spilled every
//!   N admissions and on clean shutdown, restored on boot).
//! * `astra warm inspect <file>` — [`inspect`], header-level validity
//!   against the current engine without importing anything.

use crate::coordinator::{
    AuditContender, AuditDecision, AuditFunnel, AuditMargins, AuditPool, AuditRound, AuditWave,
    FrontierCandidate, FrontierReport, PhaseBreakdown, ScoredStrategy, ScoringCore, SearchAudit,
    SearchReport,
};
use crate::cost::{CostBreakdown, CostConsts, EtaProvider, MemoRows, StageTime};
use crate::gbdt::Forest;
use crate::gpu::GpuCatalog;
use crate::json::{self, Value};
use crate::pareto::{OptimalPool, PoolEntry};
use crate::pricing::PriceBook;
use crate::service::fingerprint::Fnv64;
use crate::strategy::{
    ClusterAssignment, ParallelStrategy, Recompute, RecomputeMethod, Segment,
};
use crate::{AstraError, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// On-disk format version; bumped whenever a row encoding changes. Old
/// snapshots are rejected wholesale (cold start), never misread.
pub const FORMAT_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Bit-exact scalar encoding
// ---------------------------------------------------------------------------

fn hex64(x: u64) -> String {
    format!("{x:016x}")
}

/// `f64` as its bit pattern — the only encoding that restores bit-identical.
fn bits(x: f64) -> Value {
    Value::Str(hex64(x.to_bits()))
}

fn parse_hex(v: &Value) -> Option<u64> {
    let s = v.as_str()?;
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

fn req_hex(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(parse_hex)
        .ok_or_else(|| AstraError::Json(format!("missing/invalid hex64 field '{key}'")))
}

fn req_bits(v: &Value, key: &str) -> Result<f64> {
    req_hex(v, key).map(f64::from_bits)
}

fn req_bool(v: &Value, key: &str) -> Result<bool> {
    v.get(key)
        .and_then(Value::as_bool)
        .ok_or_else(|| AstraError::Json(format!("missing/invalid bool field '{key}'")))
}

// ---------------------------------------------------------------------------
// Engine identity digests
// ---------------------------------------------------------------------------

/// The engine-identity half of a scope header: everything memo'd values
/// depend on besides their keys. Two engines with equal `EngineMeta` (and
/// equal scope keys) compute bit-identical memo values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EngineMeta {
    pub catalog: u64,
    pub eta: String,
    pub consts: u64,
    pub book: u64,
    /// Rate-free membership digest of the same book — the book pin used by
    /// `"cache.frontier"` scopes (see [`book_membership_digest`]).
    pub book_membership: u64,
}

impl EngineMeta {
    /// Digest the identity from its parts. Forest digests walk every tree
    /// node, so cores compute this once at construction and hand out
    /// [`ScoringCore::engine_meta`] thereafter.
    pub fn new(
        catalog: &GpuCatalog,
        eta: &EtaProvider,
        consts: &CostConsts,
        book: &PriceBook,
    ) -> EngineMeta {
        EngineMeta {
            catalog: catalog_digest(catalog),
            eta: eta_identity(eta),
            consts: consts_digest(consts),
            book: book_digest(book),
            book_membership: book_membership_digest(book),
        }
    }

    /// The live identity of a [`ScoringCore`] — the core's cached copy
    /// (digested once at construction), not a recomputation.
    pub fn of_core(core: &ScoringCore) -> EngineMeta {
        core.engine_meta().clone()
    }
}

/// Digest over every result-relevant catalog field (specs in order plus
/// topology). Also pins GPU *indices*: memo keys and snapshot rows store
/// catalog indices, so a reordered catalog must (and does) change this.
pub fn catalog_digest(c: &GpuCatalog) -> u64 {
    let mut h = Fnv64::new();
    h.field_str("catalog", "v1")
        .field_usize("gpus_per_node", c.gpus_per_node)
        .field_usize("len", c.len());
    for s in c.all() {
        h.field_str("name", &s.name)
            .field_f64("mem_gib", s.mem_gib)
            .field_f64("peak", s.peak_tflops_bf16)
            .field_f64("hbm", s.hbm_gbs)
            .field_f64("nvlink", s.nvlink_gbs)
            .field_f64("inter", s.internode_gbs)
            .field_f64("pcie", s.pcie_gbs)
            .field_f64("price", s.price_per_hour)
            .field_f64("util_max", s.eff.util_max)
            .field_f64("launch", s.eff.launch_overhead_s)
            .field_f64("skinny_dim", s.eff.skinny_dim)
            .field_f64("skinny_pen", s.eff.skinny_penalty)
            .field_f64("mbi", s.eff.mem_bound_intensity)
            .field_f64("lat", s.eff.comm_latency_s)
            .field_f64("ceff", s.eff.comm_eff_max);
    }
    h.finish()
}

/// Digest over the [`CostConsts`] composition constants.
pub fn consts_digest(c: &CostConsts) -> u64 {
    let mut h = Fnv64::new();
    h.field_str("consts", "v1")
        .field_f64("p2p_hide", c.p2p_hide)
        .field_f64("grad_reduce_hide", c.grad_reduce_hide)
        .field_f64("param_gather_hide", c.param_gather_hide)
        .field_f64("tp_hide", c.tp_hide)
        .field_f64("adam_bytes", c.adam_bytes_per_param)
        .field_f64("host_ddr", c.host_ddr_gbs)
        .field_f64("offload_hide", c.offload_hide);
    h.finish()
}

fn forest_digest(h: &mut Fnv64, tag: &str, f: &Forest) {
    h.field_str("forest", tag)
        .field_usize("n_features", f.n_features)
        .field_u64("base", f.base.to_bits() as u64)
        .field_u64("lr", f.lr.to_bits() as u64)
        .field_usize("trees", f.trees.len());
    for t in &f.trees {
        h.field_usize("depth", t.depth);
        for &x in &t.feat {
            h.field_u64("f", x as u64);
        }
        for &x in &t.thresh {
            h.field_u64("t", x.to_bits() as u64);
        }
        for &x in &t.leaf {
            h.field_u64("l", x.to_bits() as u64);
        }
    }
}

/// Identity of the η source: `"analytic"` (the curves are part of the
/// catalog digest) or `"forests:<hex16>"` over every tree node — retrained
/// forests must invalidate spilled memos.
pub fn eta_identity(eta: &EtaProvider) -> String {
    match eta {
        EtaProvider::Analytic => "analytic".to_string(),
        EtaProvider::Forests(f) => {
            let mut h = Fnv64::new();
            forest_digest(&mut h, "comp", &f.comp);
            forest_digest(&mut h, "comm", &f.comm);
            format!("forests:{}", hex64(h.finish()))
        }
    }
}

/// Digest over the full rate card, delegating to the request
/// fingerprint's field walk so the two book hashes can never silently
/// diverge when [`PriceBook`] grows a field.
pub fn book_digest(book: &PriceBook) -> u64 {
    let mut h = Fnv64::new();
    h.field_str("book", "v1");
    crate::service::fingerprint::hash_book(&mut h, book);
    h.finish()
}

/// Digest over the book's *membership* only — which GPU types carry a rate
/// card, not what the rates are. This is the book pin for
/// `"cache.frontier"` scopes: a frontier's candidate set is independent of
/// rates, so spilled frontiers must survive rate-only book edits (they are
/// re-priced at serve time) and be invalidated only when a card appears or
/// disappears, which can change frontier membership.
pub fn book_membership_digest(book: &PriceBook) -> u64 {
    let mut h = Fnv64::new();
    h.field_str("book.membership", "v1");
    crate::service::fingerprint::hash_book_membership(&mut h, book);
    h.finish()
}

// ---------------------------------------------------------------------------
// Stats + counters
// ---------------------------------------------------------------------------

/// Outcome of one spill.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Memo scopes written.
    pub scopes: usize,
    /// Result-cache entries written.
    pub cache_entries: usize,
    /// Snapshot size on disk.
    pub bytes: u64,
}

/// Outcome of one restore.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RestoreStats {
    /// Memo scopes that validated and imported.
    pub scopes_restored: usize,
    /// Scopes/sections skipped (digest or version mismatch, corruption,
    /// truncation) — the cold-start degradations.
    pub scopes_rejected: usize,
    /// Result-cache entries that validated (insertion is the caller's job).
    pub cache_entries: usize,
    pub stage_rows: usize,
    pub sync_rows: usize,
}

/// Lifetime persistence counters, owned by the [`ScoringCore`] so operators
/// can observe registry state across restarts (`astra stats` / the wire
/// `stats` response).
#[derive(Default)]
pub struct PersistCounters {
    scopes_spilled: AtomicU64,
    scopes_restored: AtomicU64,
    scopes_rejected: AtomicU64,
    /// Scopes left out of budgeted spills (`max_snapshot_bytes`), LRU first.
    scopes_dropped: AtomicU64,
    bytes_on_disk: AtomicU64,
    cache_spilled: AtomicU64,
    cache_restored: AtomicU64,
}

impl PersistCounters {
    pub fn note_spill(&self, s: &SpillStats) {
        self.scopes_spilled.fetch_add(s.scopes as u64, Ordering::Relaxed);
        self.cache_spilled.fetch_add(s.cache_entries as u64, Ordering::Relaxed);
        // A gauge, not a counter: the latest snapshot's size.
        self.bytes_on_disk.store(s.bytes, Ordering::Relaxed);
        crate::telemetry::counter_macro!("astra_persist_scopes_spilled_total").add(s.scopes as u64);
        crate::telemetry::counter_macro!("astra_persist_cache_spilled_total")
            .add(s.cache_entries as u64);
        crate::telemetry::gauge_macro!("astra_persist_snapshot_bytes").set(s.bytes as i64);
    }

    /// Folds in a restore's memo-scope outcome. Cache insertions are
    /// counted by whoever actually inserts ([`Self::note_cache_restored`]).
    pub fn note_restore(&self, s: &RestoreStats) {
        self.scopes_restored.fetch_add(s.scopes_restored as u64, Ordering::Relaxed);
        self.scopes_rejected.fetch_add(s.scopes_rejected as u64, Ordering::Relaxed);
        crate::telemetry::counter_macro!("astra_persist_scopes_restored_total")
            .add(s.scopes_restored as u64);
        crate::telemetry::counter_macro!("astra_persist_scopes_rejected_total")
            .add(s.scopes_rejected as u64);
    }

    pub fn note_cache_restored(&self, entries: u64) {
        self.cache_restored.fetch_add(entries, Ordering::Relaxed);
        crate::telemetry::counter_macro!("astra_persist_cache_restored_total").add(entries);
    }

    /// Scopes a byte-budgeted spill left out (least-recently-used first).
    pub fn note_scopes_dropped(&self, scopes: u64) {
        self.scopes_dropped.fetch_add(scopes, Ordering::Relaxed);
        crate::telemetry::counter_macro!("astra_persist_scopes_dropped_total").add(scopes);
    }

    /// Update the on-disk size gauge from a freshly *read* snapshot, so
    /// `persist_bytes` is meaningful right after a restore-on-boot (not
    /// only after the first spill).
    pub fn note_snapshot_bytes(&self, bytes: u64) {
        self.bytes_on_disk.store(bytes, Ordering::Relaxed);
        crate::telemetry::gauge_macro!("astra_persist_snapshot_bytes").set(bytes as i64);
    }

    pub fn snapshot(&self) -> PersistSnapshot {
        PersistSnapshot {
            scopes_spilled: self.scopes_spilled.load(Ordering::Relaxed),
            scopes_restored: self.scopes_restored.load(Ordering::Relaxed),
            scopes_rejected: self.scopes_rejected.load(Ordering::Relaxed),
            scopes_dropped: self.scopes_dropped.load(Ordering::Relaxed),
            bytes_on_disk: self.bytes_on_disk.load(Ordering::Relaxed),
            cache_entries_spilled: self.cache_spilled.load(Ordering::Relaxed),
            cache_entries_restored: self.cache_restored.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data view of [`PersistCounters`] for the stats line.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PersistSnapshot {
    pub scopes_spilled: u64,
    pub scopes_restored: u64,
    pub scopes_rejected: u64,
    /// Scopes dropped from budgeted spills (`max_snapshot_bytes`), LRU first.
    pub scopes_dropped: u64,
    pub bytes_on_disk: u64,
    pub cache_entries_spilled: u64,
    pub cache_entries_restored: u64,
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Builds a snapshot in memory and commits it atomically (temp file +
/// rename), so a crash mid-spill can never leave a half-written file at
/// the published path.
pub struct WarmWriter {
    out: String,
    scopes: usize,
    cache_entries: usize,
}

impl Default for WarmWriter {
    fn default() -> Self {
        WarmWriter::new()
    }
}

impl WarmWriter {
    pub fn new() -> WarmWriter {
        let mut out = String::new();
        out.push_str(&json::to_string(&Value::obj().set("astra_warm", FORMAT_VERSION)));
        out.push('\n');
        WarmWriter { out, scopes: 0, cache_entries: 0 }
    }

    fn push_line(&mut self, v: &Value) {
        self.out.push_str(&json::to_string(v));
        self.out.push('\n');
    }

    /// Scope header skeleton. `book` is the caller's pick of book pin:
    /// the full [`book_digest`] for `"memo"`/`"cache"` scopes, the rate-free
    /// [`book_membership_digest`] for `"cache.frontier"` scopes.
    fn meta_header(meta: &EngineMeta, kind: &str, book: u64) -> Value {
        Value::obj()
            .set("kind", kind)
            .set("format", FORMAT_VERSION)
            .set("catalog", hex64(meta.catalog))
            .set("eta", meta.eta.as_str())
            .set("consts", hex64(meta.consts))
            .set("book", hex64(book))
    }

    fn push_row_to(out: &mut String, t: &str, k: &[u64], v: &[u64; 3], sum: &mut Fnv64) {
        for &x in k {
            sum.field_u64("k", x);
        }
        for &x in v {
            sum.field_u64("v", x);
        }
        let kv: Vec<Value> = k.iter().map(|&x| Value::from(x)).collect();
        let vv: Vec<Value> = v.iter().map(|&x| Value::Str(hex64(x))).collect();
        out.push_str(&json::to_string(
            &Value::obj().set("t", t).set("k", Value::Arr(kv)).set("v", Value::Arr(vv)),
        ));
        out.push('\n');
    }

    /// One memo scope rendered standalone — header, sorted rows, checksummed
    /// footer — so the byte-budgeted spill path
    /// ([`crate::coordinator::ScoringCore::export_warm_within`]) can size a
    /// section before committing it to a snapshot.
    pub fn memo_scope_section(key: u64, rows: &MemoRows, meta: &EngineMeta) -> String {
        let mut out = String::new();
        let header = Self::meta_header(meta, "memo", meta.book)
            .set("key", hex64(key))
            .set("stage_rows", rows.stages.len())
            .set("sync_rows", rows.syncs.len());
        out.push_str(&json::to_string(&Value::obj().set("scope", header)));
        out.push('\n');
        let mut sum = Fnv64::new();
        for (k, v) in &rows.stages {
            Self::push_row_to(&mut out, "stage", k, v, &mut sum);
        }
        for (k, v) in &rows.syncs {
            Self::push_row_to(&mut out, "sync", k, v, &mut sum);
        }
        out.push_str(&json::to_string(
            &Value::obj()
                .set("end", hex64(key))
                .set("rows", rows.stages.len() + rows.syncs.len())
                .set("sum", hex64(sum.finish())),
        ));
        out.push('\n');
        out
    }

    /// Append a section produced by [`Self::memo_scope_section`].
    pub fn push_memo_section(&mut self, section: &str) {
        self.out.push_str(section);
        self.scopes += 1;
    }

    /// One memo scope: header, sorted rows (the caller exports them via
    /// [`crate::cost::SharedCostMemo::export_rows`], which drains the
    /// stripe locks shard by shard), checksummed footer.
    pub fn memo_scope(&mut self, key: u64, rows: &MemoRows, meta: &EngineMeta) {
        let section = Self::memo_scope_section(key, rows, meta);
        self.push_memo_section(&section);
    }

    /// Serialized size so far (file header plus appended sections) — the
    /// byte-budget accounting input.
    pub fn len(&self) -> usize {
        self.out.len()
    }

    /// Never true (the file header is written at construction); present to
    /// satisfy the `len`/`is_empty` API convention.
    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }

    /// The result-cache section: one row per entry, fingerprint + the
    /// bit-exact report codec, checksummed over the serialized bytes.
    pub fn cache_section(
        &mut self,
        entries: &[(u64, Arc<SearchReport>)],
        catalog: &GpuCatalog,
        meta: &EngineMeta,
    ) {
        self.cache_section_kind(entries, catalog, meta, "cache", meta.book);
    }

    /// Like [`Self::cache_section`] but for frontier-mode reports: the
    /// scope kind is `"cache.frontier"` and the book pin is the rate-free
    /// [`book_membership_digest`], so spilled frontiers survive rate-only
    /// price-book changes across a restart (the service re-prices them at
    /// serve time) and are invalidated only when membership could change.
    pub fn frontier_cache_section(
        &mut self,
        entries: &[(u64, Arc<SearchReport>)],
        catalog: &GpuCatalog,
        meta: &EngineMeta,
    ) {
        self.cache_section_kind(entries, catalog, meta, "cache.frontier", meta.book_membership);
    }

    fn cache_section_kind(
        &mut self,
        entries: &[(u64, Arc<SearchReport>)],
        catalog: &GpuCatalog,
        meta: &EngineMeta,
        kind: &str,
        book: u64,
    ) {
        if entries.is_empty() {
            return;
        }
        let header = Self::meta_header(meta, kind, book).set("entries", entries.len());
        self.push_line(&Value::obj().set("scope", header));
        let mut sum = Fnv64::new();
        for (fp, report) in entries {
            let rv = report_to_value(report, catalog);
            sum.field_u64("fp", *fp);
            sum.write_bytes(json::to_string(&rv).as_bytes());
            self.push_line(&Value::obj().set("fp", hex64(*fp)).set("t", "report").set("report", rv));
        }
        self.push_line(
            &Value::obj()
                .set("end", kind)
                .set("rows", entries.len())
                .set("sum", hex64(sum.finish())),
        );
        self.cache_entries += entries.len();
    }

    /// Commit atomically; returns what landed on disk. The temp name is
    /// pid-unique so two processes spilling to the same path (a serve
    /// instance plus an operator's `astra warm save`) cannot interleave
    /// into a torn file — last rename wins, both candidates are whole.
    pub fn finish_to(self, path: &Path) -> Result<SpillStats> {
        // Chaos seam: an armed `persist.spill` fails the commit before any
        // byte reaches disk — the previous snapshot (if any) stays whole.
        crate::failpoint!("persist.spill");
        let bytes = self.out.len() as u64;
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.out.as_bytes())?;
        std::fs::rename(&tmp, path)?;
        Ok(SpillStats { scopes: self.scopes, cache_entries: self.cache_entries, bytes })
    }
}

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// Everything a snapshot yielded under the caller's [`EngineMeta`]:
/// validated memo scopes and cache entries, plus rejection accounting.
pub struct RestoreSet {
    pub memo_scopes: Vec<(u64, MemoRows)>,
    pub cache: Vec<(u64, SearchReport)>,
    pub scopes_rejected: usize,
    pub stage_rows: usize,
    pub sync_rows: usize,
}

impl RestoreSet {
    fn empty() -> RestoreSet {
        RestoreSet {
            memo_scopes: Vec::new(),
            cache: Vec::new(),
            scopes_rejected: 0,
            stage_rows: 0,
            sync_rows: 0,
        }
    }

    pub fn stats(&self) -> RestoreStats {
        RestoreStats {
            scopes_restored: self.memo_scopes.len(),
            scopes_rejected: self.scopes_rejected,
            cache_entries: self.cache.len(),
            stage_rows: self.stage_rows,
            sync_rows: self.sync_rows,
        }
    }
}

fn header_matches_with_book(h: &Value, meta: &EngineMeta, book: u64) -> bool {
    h.get("format").and_then(Value::as_u64) == Some(FORMAT_VERSION)
        && h.get("catalog").and_then(parse_hex) == Some(meta.catalog)
        && h.opt_str("eta") == Some(meta.eta.as_str())
        && h.get("consts").and_then(parse_hex) == Some(meta.consts)
        && h.get("book").and_then(parse_hex) == Some(book)
}

fn header_matches(h: &Value, meta: &EngineMeta) -> bool {
    header_matches_with_book(h, meta, meta.book)
}

fn parse_memo_row(line: &str) -> Option<(String, Vec<u64>, [u64; 3])> {
    let v = json::parse(line).ok()?;
    let t = v.opt_str("t")?.to_string();
    let k: Option<Vec<u64>> = v.get("k")?.as_arr()?.iter().map(Value::as_u64).collect();
    let k = k?;
    let vals = v.get("v")?.as_arr()?;
    if vals.len() != 3 {
        return None;
    }
    let mut out = [0u64; 3];
    for (i, x) in vals.iter().enumerate() {
        out[i] = parse_hex(x)?;
    }
    Some((t, k, out))
}

/// Footer check shared by both scope kinds. `None` when the line is not
/// even a footer (sync lost — abort the file), `Some(ok)` otherwise.
fn check_footer(line: Option<&str>, end: &Value, rows: usize, sum: u64) -> Option<bool> {
    let v = json::parse(line?).ok()?;
    let end_field = v.get("end")?;
    Some(
        end_field == end
            && v.opt_usize("rows") == Some(rows)
            && v.get("sum").and_then(parse_hex) == Some(sum),
    )
}

/// Parse one memo scope. Returns `false` when the stream can no longer be
/// trusted (truncation / lost sync) and parsing must stop.
fn read_memo_scope(
    header: &Value,
    lines: &mut std::str::Lines<'_>,
    meta: &EngineMeta,
    set: &mut RestoreSet,
) -> bool {
    let (ns, nq, key) = match (
        header.opt_usize("stage_rows"),
        header.opt_usize("sync_rows"),
        header.get("key").and_then(parse_hex),
    ) {
        (Some(ns), Some(nq), Some(key)) => (ns, nq, key),
        // Malformed header: the row count is unknown, so the rest of the
        // file cannot be skipped reliably.
        _ => {
            set.scopes_rejected += 1;
            return false;
        }
    };
    let accept = header_matches(header, meta);
    let mut rows = MemoRows::default();
    let mut sum = Fnv64::new();
    let mut good = true;
    for i in 0..(ns + nq) {
        let Some(line) = lines.next() else {
            // Truncated mid-scope.
            set.scopes_rejected += 1;
            return false;
        };
        if !good {
            continue; // keep consuming the declared rows to stay in sync
        }
        match parse_memo_row(line) {
            Some((t, k, v)) => {
                for &x in &k {
                    sum.field_u64("k", x);
                }
                for &x in &v {
                    sum.field_u64("v", x);
                }
                if i < ns && t == "stage" && k.len() == 13 {
                    let mut arr = [0u64; 13];
                    arr.copy_from_slice(&k);
                    rows.stages.push((arr, v));
                } else if i >= ns && t == "sync" && k.len() == 10 {
                    let mut arr = [0u64; 10];
                    arr.copy_from_slice(&k);
                    rows.syncs.push((arr, v));
                } else {
                    good = false;
                }
            }
            None => good = false,
        }
    }
    let footer = check_footer(lines.next(), &Value::Str(hex64(key)), ns + nq, sum.finish());
    let Some(footer_ok) = footer else {
        set.scopes_rejected += 1;
        return false;
    };
    if accept && good && footer_ok && rows.validate() {
        set.stage_rows += rows.stages.len();
        set.sync_rows += rows.syncs.len();
        set.memo_scopes.push((key, rows));
    } else {
        set.scopes_rejected += 1;
    }
    true
}

/// Parse the cache section; same contract as [`read_memo_scope`]. With
/// `want_cache` off the rows are still consumed and checksummed (sync and
/// integrity accounting are unchanged) but the expensive per-report struct
/// decode is skipped and nothing is collected.
fn read_cache_scope(
    header: &Value,
    lines: &mut std::str::Lines<'_>,
    catalog: &GpuCatalog,
    meta: &EngineMeta,
    kind: &str,
    book: u64,
    want_cache: bool,
    set: &mut RestoreSet,
) -> bool {
    let Some(n) = header.opt_usize("entries") else {
        set.scopes_rejected += 1;
        return false;
    };
    let accept = header_matches_with_book(header, meta, book);
    let mut sum = Fnv64::new();
    let mut good = true;
    // The count is untrusted header data: clamp the pre-allocation so a
    // corrupt header cannot abort the process on an absurd reserve (the
    // row loop self-limits — a lying count runs out of lines and rejects).
    let mut entries: Vec<(u64, SearchReport)> = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let Some(line) = lines.next() else {
            set.scopes_rejected += 1;
            return false;
        };
        if !good {
            continue;
        }
        let parsed = json::parse(line).ok().and_then(|v| {
            let fp = v.get("fp").and_then(parse_hex)?;
            let rv = v.get("report")?.clone();
            Some((fp, rv))
        });
        match parsed {
            Some((fp, rv)) => {
                sum.field_u64("fp", fp);
                sum.write_bytes(json::to_string(&rv).as_bytes());
                if want_cache {
                    match report_from_value(&rv, catalog) {
                        Ok(report) => entries.push((fp, report)),
                        Err(_) => good = false,
                    }
                }
            }
            None => good = false,
        }
    }
    let footer = check_footer(lines.next(), &Value::Str(kind.to_string()), n, sum.finish());
    let Some(footer_ok) = footer else {
        set.scopes_rejected += 1;
        return false;
    };
    if accept && good && footer_ok {
        set.cache.extend(entries);
    } else {
        set.scopes_rejected += 1;
    }
    true
}

/// Parse a snapshot against the caller's engine identity. Infallible by
/// design: anything that does not validate is skipped and counted, so a
/// bad snapshot degrades to a cold start rather than an error.
pub fn read_warm(text: &str, catalog: &GpuCatalog, meta: &EngineMeta) -> RestoreSet {
    read_warm_filtered(text, catalog, meta, true)
}

/// [`read_warm`] with the cache section's per-report decode made optional:
/// memo-only consumers (`astra warm load`, `search --warm-load`,
/// `include_cache: false` services) skip reconstructing reports they would
/// immediately discard.
pub fn read_warm_filtered(
    text: &str,
    catalog: &GpuCatalog,
    meta: &EngineMeta,
    want_cache: bool,
) -> RestoreSet {
    let mut set = RestoreSet::empty();
    // Chaos seam: an armed `persist.decode` makes the snapshot read like a
    // corrupt header — the reject-and-cold-start path, never an error.
    let decode_fault =
        crate::resilience::failpoint::should_fire("persist.decode").is_some();
    let mut lines = text.lines();
    let header_ok = !decode_fault
        && lines
            .next()
            .and_then(|l| json::parse(l).ok())
            .and_then(|v| v.get("astra_warm").and_then(Value::as_u64))
            == Some(FORMAT_VERSION);
    if !header_ok {
        set.scopes_rejected += 1;
        return set;
    }
    while let Some(line) = lines.next() {
        if line.trim().is_empty() {
            continue;
        }
        let header = json::parse(line).ok().and_then(|v| v.get("scope").cloned());
        let Some(header) = header else {
            // A stray non-scope line means sync is lost; nothing after it
            // can be attributed to a scope.
            set.scopes_rejected += 1;
            return set;
        };
        let go = match header.opt_str("kind") {
            Some("memo") => read_memo_scope(&header, &mut lines, meta, &mut set),
            Some("cache") => read_cache_scope(
                &header, &mut lines, catalog, meta, "cache", meta.book, want_cache, &mut set,
            ),
            Some("cache.frontier") => read_cache_scope(
                &header,
                &mut lines,
                catalog,
                meta,
                "cache.frontier",
                meta.book_membership,
                want_cache,
                &mut set,
            ),
            _ => {
                set.scopes_rejected += 1;
                false
            }
        };
        if !go {
            return set;
        }
    }
    set
}

// ---------------------------------------------------------------------------
// Inspection (header-level; no import)
// ---------------------------------------------------------------------------

/// One scope's header summary for `astra warm inspect`.
#[derive(Debug, Clone)]
pub struct ScopeInfo {
    pub kind: String,
    /// Scope key (memo) or entry count (cache).
    pub detail: String,
    pub rows: usize,
    /// `"ok"` or the first mismatching header field. Header-level only —
    /// row checksums are verified at restore time.
    pub status: String,
}

fn header_status(h: &Value, meta: &EngineMeta) -> String {
    if h.get("format").and_then(Value::as_u64) != Some(FORMAT_VERSION) {
        return "format mismatch".to_string();
    }
    if h.get("catalog").and_then(parse_hex) != Some(meta.catalog) {
        return "catalog digest mismatch".to_string();
    }
    if h.opt_str("eta") != Some(meta.eta.as_str()) {
        return "eta identity mismatch".to_string();
    }
    if h.get("consts").and_then(parse_hex) != Some(meta.consts) {
        return "cost-consts digest mismatch".to_string();
    }
    // Frontier scopes pin the rate-free membership digest, not the full card.
    if h.opt_str("kind") == Some("cache.frontier") {
        if h.get("book").and_then(parse_hex) != Some(meta.book_membership) {
            return "price-book membership mismatch".to_string();
        }
    } else if h.get("book").and_then(parse_hex) != Some(meta.book) {
        return "price-book digest mismatch".to_string();
    }
    "ok".to_string()
}

/// Walk a snapshot's scope headers and report their validity against the
/// current engine identity without importing anything.
pub fn inspect(text: &str, meta: &EngineMeta) -> Vec<ScopeInfo> {
    let mut out = Vec::new();
    let mut lines = text.lines();
    let header_ok = lines
        .next()
        .and_then(|l| json::parse(l).ok())
        .and_then(|v| v.get("astra_warm").and_then(Value::as_u64))
        == Some(FORMAT_VERSION);
    if !header_ok {
        out.push(ScopeInfo {
            kind: "file".to_string(),
            detail: String::new(),
            rows: 0,
            status: "unsupported file header".to_string(),
        });
        return out;
    }
    for line in lines {
        let Some(h) = json::parse(line).ok().and_then(|v| v.get("scope").cloned()) else {
            continue;
        };
        let kind = h.opt_str("kind").unwrap_or("?").to_string();
        let (detail, rows) = match kind.as_str() {
            "memo" => (
                h.opt_str("key").unwrap_or("?").to_string(),
                h.opt_usize("stage_rows").unwrap_or(0) + h.opt_usize("sync_rows").unwrap_or(0),
            ),
            "cache" => ("result cache".to_string(), h.opt_usize("entries").unwrap_or(0)),
            "cache.frontier" => ("frontier cache".to_string(), h.opt_usize("entries").unwrap_or(0)),
            _ => ("?".to_string(), 0),
        };
        out.push(ScopeInfo { kind, detail, rows, status: header_status(&h, meta) });
    }
    out
}

// ---------------------------------------------------------------------------
// Bit-exact SearchReport codec (the cache payload)
// ---------------------------------------------------------------------------

fn strategy_to_value(s: &ParallelStrategy, catalog: &GpuCatalog) -> Value {
    let segs: Vec<Value> = s
        .cluster
        .segments
        .iter()
        .map(|seg| {
            Value::obj()
                .set("gpu", catalog.spec(seg.gpu).name.as_str())
                .set("stages", seg.stages)
                .set("layers", seg.layers_per_stage)
        })
        .collect();
    Value::obj()
        .set("segments", Value::Arr(segs))
        .set("tp", s.tp)
        .set("dp", s.dp)
        .set("mbs", s.micro_batch)
        .set("gbs", s.global_batch)
        .set("vpp", s.vpp)
        .set("ep", s.ep)
        .set("sp", s.sequence_parallel)
        .set("dist_opt", s.use_distributed_optimizer)
        .set("recompute", s.recompute.as_str())
        .set("rc_method", s.recompute_method.as_str())
        .set("rc_layers", s.recompute_num_layers)
        .set("offload", s.offload_optimizer)
        .set("ovl_grad", s.overlap_grad_reduce)
        .set("ovl_param", s.overlap_param_gather)
        .set("ovl_p2p", s.overlap_p2p)
        .set("ovl_tp", s.tp_comm_overlap)
        .set("flash", s.use_flash_attn)
}

fn strategy_from_value(v: &Value, catalog: &GpuCatalog) -> Result<ParallelStrategy> {
    let mut segments = Vec::new();
    for sv in v.req_arr("segments")? {
        segments.push(Segment {
            gpu: catalog.find(sv.req_str("gpu")?)?,
            stages: sv.req_usize("stages")?,
            layers_per_stage: sv.req_usize("layers")?,
        });
    }
    let recompute = Recompute::parse(v.req_str("recompute")?)
        .ok_or_else(|| AstraError::Json("bad recompute variant".into()))?;
    let recompute_method = RecomputeMethod::parse(v.req_str("rc_method")?)
        .ok_or_else(|| AstraError::Json("bad recompute method".into()))?;
    Ok(ParallelStrategy {
        cluster: ClusterAssignment { segments },
        tp: v.req_usize("tp")?,
        dp: v.req_usize("dp")?,
        micro_batch: v.req_usize("mbs")?,
        global_batch: v.req_usize("gbs")?,
        vpp: v.req_usize("vpp")?,
        sequence_parallel: req_bool(v, "sp")?,
        use_distributed_optimizer: req_bool(v, "dist_opt")?,
        recompute,
        recompute_method,
        recompute_num_layers: v.req_usize("rc_layers")?,
        offload_optimizer: req_bool(v, "offload")?,
        overlap_grad_reduce: req_bool(v, "ovl_grad")?,
        overlap_param_gather: req_bool(v, "ovl_param")?,
        overlap_p2p: req_bool(v, "ovl_p2p")?,
        tp_comm_overlap: req_bool(v, "ovl_tp")?,
        use_flash_attn: req_bool(v, "flash")?,
        ep: v.req_usize("ep")?,
    })
}

fn cost_to_value(c: &CostBreakdown) -> Value {
    let st: Vec<Value> = c
        .stage_times
        .iter()
        .map(|t| Value::Arr(vec![bits(t.fwd), bits(t.bwd), bits(t.p2p)]))
        .collect();
    Value::obj()
        .set("stage_times", Value::Arr(st))
        .set("pipeline_fwd", bits(c.pipeline_fwd))
        .set("pipeline_bwd", bits(c.pipeline_bwd))
        .set("dp_time", bits(c.dp_time))
        .set("optimizer_time", bits(c.optimizer_time))
        .set("offload_time", bits(c.offload_time))
        .set("step_time", bits(c.step_time))
        .set("tokens_per_s", bits(c.tokens_per_s))
        .set("mfu", bits(c.mfu))
}

fn cost_from_value(v: &Value) -> Result<CostBreakdown> {
    let mut stage_times = Vec::new();
    for tv in v.req_arr("stage_times")? {
        let parts = tv
            .as_arr()
            .filter(|a| a.len() == 3)
            .ok_or_else(|| AstraError::Json("bad stage_times row".into()))?;
        let mut t = [0.0f64; 3];
        for (i, p) in parts.iter().enumerate() {
            t[i] = parse_hex(p)
                .map(f64::from_bits)
                .ok_or_else(|| AstraError::Json("bad stage time bits".into()))?;
        }
        stage_times.push(StageTime { fwd: t[0], bwd: t[1], p2p: t[2] });
    }
    Ok(CostBreakdown {
        stage_times,
        pipeline_fwd: req_bits(v, "pipeline_fwd")?,
        pipeline_bwd: req_bits(v, "pipeline_bwd")?,
        dp_time: req_bits(v, "dp_time")?,
        optimizer_time: req_bits(v, "optimizer_time")?,
        offload_time: req_bits(v, "offload_time")?,
        step_time: req_bits(v, "step_time")?,
        tokens_per_s: req_bits(v, "tokens_per_s")?,
        mfu: req_bits(v, "mfu")?,
    })
}

/// Full-fidelity [`SearchAudit`] encoding — every field (including the
/// load-dependent memo/wave observability the canonical
/// [`crate::report::audit_json`] elides), floats as bit patterns, so a
/// restored cache entry replays the exact audit it was stored with.
fn audit_to_value(a: &SearchAudit) -> Value {
    let rounds: Vec<Value> = a
        .rounds
        .iter()
        .map(|r| {
            let pools: Vec<Value> = r
                .pools
                .iter()
                .map(|p| {
                    let gpus: Vec<Value> = p
                        .gpus
                        .iter()
                        .map(|(g, n)| Value::obj().set("gpu", g.as_str()).set("n", *n))
                        .collect();
                    let mut v = Value::obj()
                        .set("pool", p.pool)
                        .set("gpus", Value::Arr(gpus))
                        .set("tp", p.tp)
                        .set("dp", p.dp)
                        .set("ub_tput", bits(p.ub_tput))
                        .set("lb_usd", bits(p.lb_usd))
                        .set("decision", p.decision.tag());
                    match p.decision {
                        AuditDecision::Admitted => {}
                        AuditDecision::PrunedBudget { lb_usd, budget } => {
                            v = v.set("ev_lb_usd", bits(lb_usd)).set("ev_budget", bits(budget));
                        }
                        AuditDecision::PrunedDominated { by } => {
                            v = v.set("ev_by_tput", bits(by.0)).set("ev_by_usd", bits(by.1));
                        }
                    }
                    if let Some(f) = &p.funnel {
                        v = v.set(
                            "funnel",
                            Value::obj()
                                .set("expanded", f.expanded)
                                .set("rules_rejected", f.rules_rejected)
                                .set("mem_rejected", f.mem_rejected)
                                .set("scored", f.scored)
                                .set("memo_hits", f.memo_hits)
                                .set("memo_misses", f.memo_misses),
                        );
                    }
                    v
                })
                .collect();
            Value::obj().set("round", r.round).set("total", r.total).set("pools", Value::Arr(pools))
        })
        .collect();
    let waves: Vec<Value> = a
        .waves
        .iter()
        .map(|w| {
            Value::obj()
                .set("wave", w.wave)
                .set("rounds", w.rounds)
                .set("speculated", w.speculated)
                .set("wasted", w.wasted)
        })
        .collect();
    let mut out = Value::obj().set("rounds", Value::Arr(rounds)).set("waves", Value::Arr(waves));
    if let Some(m) = &a.margins {
        let cont = |c: &AuditContender| {
            Value::obj()
                .set("summary", c.summary.as_str())
                .set("step", bits(c.step_time_s))
                .set("tput", bits(c.tokens_per_s))
                .set("usd", bits(c.money_usd))
        };
        let mut mv = Value::obj()
            .set("winner", cont(&m.winner))
            .set("step_margin", bits(m.step_time_margin_s))
            .set("tput_margin", bits(m.tokens_per_s_margin))
            .set("usd_margin", bits(m.money_margin_usd));
        if let Some(ru) = &m.runner_up {
            mv = mv.set("runner_up", cont(ru));
        }
        out = out.set("margins", mv);
    }
    out
}

/// Inverse of [`audit_to_value`].
fn audit_from_value(v: &Value) -> Result<SearchAudit> {
    let mut rounds = Vec::new();
    for rv in v.req_arr("rounds")? {
        let mut pools = Vec::new();
        for pv in rv.req_arr("pools")? {
            let mut gpus = Vec::new();
            for gv in pv.req_arr("gpus")? {
                let name = gv
                    .get("gpu")
                    .and_then(Value::as_str)
                    .ok_or_else(|| AstraError::Json("missing audit gpu name".into()))?;
                gpus.push((name.to_string(), gv.req_usize("n")?));
            }
            let decision = match pv.get("decision").and_then(Value::as_str) {
                Some("admitted") => AuditDecision::Admitted,
                Some("pruned_budget") => AuditDecision::PrunedBudget {
                    lb_usd: req_bits(pv, "ev_lb_usd")?,
                    budget: req_bits(pv, "ev_budget")?,
                },
                Some("pruned_dominated") => AuditDecision::PrunedDominated {
                    by: (req_bits(pv, "ev_by_tput")?, req_bits(pv, "ev_by_usd")?),
                },
                _ => return Err(AstraError::Json("bad audit decision tag".into())),
            };
            let funnel = match pv.get("funnel") {
                Some(fv) => Some(AuditFunnel {
                    expanded: fv.req_usize("expanded")?,
                    rules_rejected: fv.req_usize("rules_rejected")?,
                    mem_rejected: fv.req_usize("mem_rejected")?,
                    scored: fv.req_usize("scored")?,
                    memo_hits: fv
                        .get("memo_hits")
                        .and_then(Value::as_u64)
                        .ok_or_else(|| AstraError::Json("bad audit memo_hits".into()))?,
                    memo_misses: fv
                        .get("memo_misses")
                        .and_then(Value::as_u64)
                        .ok_or_else(|| AstraError::Json("bad audit memo_misses".into()))?,
                }),
                None => None,
            };
            pools.push(AuditPool {
                pool: pv.req_usize("pool")?,
                gpus,
                tp: pv.req_usize("tp")?,
                dp: pv.req_usize("dp")?,
                ub_tput: req_bits(pv, "ub_tput")?,
                lb_usd: req_bits(pv, "lb_usd")?,
                decision,
                funnel,
            });
        }
        rounds.push(AuditRound { round: rv.req_usize("round")?, total: rv.req_usize("total")?, pools });
    }
    let mut waves = Vec::new();
    for wv in v.req_arr("waves")? {
        waves.push(AuditWave {
            wave: wv.req_usize("wave")?,
            rounds: wv.req_usize("rounds")?,
            speculated: wv.req_usize("speculated")?,
            wasted: wv.req_usize("wasted")?,
        });
    }
    let contender = |cv: &Value| -> Result<AuditContender> {
        Ok(AuditContender {
            summary: cv
                .get("summary")
                .and_then(Value::as_str)
                .ok_or_else(|| AstraError::Json("missing audit summary".into()))?
                .to_string(),
            step_time_s: req_bits(cv, "step")?,
            tokens_per_s: req_bits(cv, "tput")?,
            money_usd: req_bits(cv, "usd")?,
        })
    };
    let margins = match v.get("margins") {
        Some(mv) => Some(AuditMargins {
            winner: contender(
                mv.get("winner").ok_or_else(|| AstraError::Json("missing audit winner".into()))?,
            )?,
            runner_up: match mv.get("runner_up") {
                Some(rv) => Some(contender(rv)?),
                None => None,
            },
            step_time_margin_s: req_bits(mv, "step_margin")?,
            tokens_per_s_margin: req_bits(mv, "tput_margin")?,
            money_margin_usd: req_bits(mv, "usd_margin")?,
        }),
        None => None,
    };
    Ok(SearchAudit { rounds, waves, margins })
}

/// Full-fidelity [`SearchReport`] encoding — every field, floats as bit
/// patterns, GPUs by catalog name. Unlike [`crate::report::report_json`]
/// (the lossy canonical *result* view), this restores the exact struct so
/// a restored cache entry serves byte-identical wire responses.
pub fn report_to_value(r: &SearchReport, catalog: &GpuCatalog) -> Value {
    let top: Vec<Value> = r
        .top
        .iter()
        .map(|s| {
            Value::obj()
                .set("strategy", strategy_to_value(&s.strategy, catalog))
                .set("cost", cost_to_value(&s.cost))
                .set("money", bits(s.money_usd))
        })
        .collect();
    let pool: Vec<Value> = r
        .pool
        .entries()
        .iter()
        .map(|e| Value::obj().set("idx", e.idx).set("tput", bits(e.throughput)).set("cost", bits(e.cost)))
        .collect();
    let out = Value::obj()
        .set("generated", r.generated)
        .set("rule_filtered", r.rule_filtered)
        .set("mem_filtered", r.mem_filtered)
        .set("scored", r.scored)
        .set("pruned_pools", r.pruned_pools)
        .set("pruned_budget", r.pruned_budget)
        .set("pruned_dominated", r.pruned_dominated)
        .set("search_secs", bits(r.search_secs))
        .set("simulate_secs", bits(r.simulate_secs))
        .set(
            "phases",
            Value::obj()
                .set("compile", bits(r.phases.compile_secs))
                .set("speculate", bits(r.phases.speculate_secs))
                .set("expand_rules", bits(r.phases.expand_rules_secs))
                .set("mem_filter", bits(r.phases.mem_filter_secs))
                .set("score", bits(r.phases.score_secs))
                .set("hlo_pack", bits(r.phases.hlo_pack_secs)),
        )
        .set("memo_hits", r.memo_hits)
        .set("memo_misses", r.memo_misses)
        .set("top", Value::Arr(top))
        .set("pool", Value::Arr(pool));
    let out = match &r.frontier {
        Some(fr) => {
            let cands: Vec<Value> = fr
                .candidates
                .iter()
                .map(|c| {
                    Value::obj()
                        .set("idx", c.idx)
                        .set("strategy", strategy_to_value(&c.scored.strategy, catalog))
                        .set("cost", cost_to_value(&c.scored.cost))
                        .set("money", bits(c.scored.money_usd))
                })
                .collect();
            out.set("frontier", Value::Arr(cands))
        }
        None => out,
    };
    // The audit rides along bit-exact (same format version: the key is
    // simply absent for unaudited reports, and decoders treat a missing
    // key as `None` — old snapshots keep decoding unchanged).
    match &r.audit {
        Some(a) => out.set("audit", audit_to_value(a)),
        None => out,
    }
}

/// Inverse of [`report_to_value`].
pub fn report_from_value(v: &Value, catalog: &GpuCatalog) -> Result<SearchReport> {
    let mut top = Vec::new();
    for sv in v.req_arr("top")? {
        let strategy = strategy_from_value(
            sv.get("strategy").ok_or_else(|| AstraError::Json("missing strategy".into()))?,
            catalog,
        )?;
        let cost = cost_from_value(
            sv.get("cost").ok_or_else(|| AstraError::Json("missing cost".into()))?,
        )?;
        top.push(ScoredStrategy { strategy, cost, money_usd: req_bits(sv, "money")? });
    }
    let mut entries = Vec::new();
    for ev in v.req_arr("pool")? {
        entries.push(PoolEntry {
            idx: ev.req_usize("idx")?,
            throughput: req_bits(ev, "tput")?,
            cost: req_bits(ev, "cost")?,
        });
    }
    let req_count = |key: &str| -> Result<u64> {
        v.get(key)
            .and_then(Value::as_u64)
            .ok_or_else(|| AstraError::Json(format!("missing/invalid count field '{key}'")))
    };
    // Optional for forward-compat: format-v1 snapshots written before the
    // phase breakdown existed restore with an all-zero breakdown.
    let phases = match v.get("phases") {
        Some(pv) => PhaseBreakdown {
            compile_secs: req_bits(pv, "compile")?,
            speculate_secs: req_bits(pv, "speculate")?,
            expand_rules_secs: req_bits(pv, "expand_rules")?,
            mem_filter_secs: req_bits(pv, "mem_filter")?,
            score_secs: req_bits(pv, "score")?,
            hlo_pack_secs: req_bits(pv, "hlo_pack")?,
        },
        None => PhaseBreakdown::default(),
    };
    // Optional: only frontier-mode reports carry a candidate skeleton, and
    // snapshots written before frontier mode existed have no field at all.
    let frontier = match v.get("frontier") {
        Some(fv) => {
            let mut candidates = Vec::new();
            for cv in fv.as_arr().ok_or_else(|| AstraError::Json("bad frontier array".into()))? {
                let strategy = strategy_from_value(
                    cv.get("strategy")
                        .ok_or_else(|| AstraError::Json("missing frontier strategy".into()))?,
                    catalog,
                )?;
                let cost = cost_from_value(
                    cv.get("cost").ok_or_else(|| AstraError::Json("missing frontier cost".into()))?,
                )?;
                candidates.push(FrontierCandidate {
                    idx: cv.req_usize("idx")?,
                    scored: ScoredStrategy { strategy, cost, money_usd: req_bits(cv, "money")? },
                });
            }
            Some(FrontierReport { candidates })
        }
        None => None,
    };
    // Optional: unaudited reports (and every snapshot written before the
    // audit existed) have no key and restore with `audit: None`.
    let audit = match v.get("audit") {
        Some(av) => Some(audit_from_value(av)?),
        None => None,
    };
    // Optional for forward-compat: snapshots written before the prune-reason
    // split restore with zeros (their `pruned_pools` total is still exact).
    let opt_usize =
        |key: &str| -> usize { v.get(key).and_then(Value::as_u64).unwrap_or(0) as usize };
    Ok(SearchReport {
        generated: v.req_usize("generated")?,
        rule_filtered: v.req_usize("rule_filtered")?,
        mem_filtered: v.req_usize("mem_filtered")?,
        scored: v.req_usize("scored")?,
        pruned_pools: v.req_usize("pruned_pools")?,
        pruned_budget: opt_usize("pruned_budget"),
        pruned_dominated: opt_usize("pruned_dominated"),
        search_secs: req_bits(v, "search_secs")?,
        simulate_secs: req_bits(v, "simulate_secs")?,
        phases,
        memo_hits: req_count("memo_hits")?,
        memo_misses: req_count("memo_misses")?,
        top,
        pool: OptimalPool::from_entries(entries),
        frontier,
        audit,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::PriceEntry;
    use crate::strategy::{ClusterAssignment, RecomputeMethod};

    fn meta() -> EngineMeta {
        EngineMeta {
            catalog: 0x1111,
            eta: "analytic".to_string(),
            consts: 0x2222,
            book: 0x3333,
            book_membership: 0x4444,
        }
    }

    fn rows() -> MemoRows {
        MemoRows {
            stages: vec![
                (
                    [1, 2, 8, 1, 2, 4, 1, 0, 0, 1, 1, 1, 1],
                    [1.5f64.to_bits(), 2.5f64.to_bits(), 0.25f64.to_bits()],
                ),
                (
                    [2, 65535, 8, 1, 2, 4, 1, 2, 4, 0, 0, 0, 1],
                    [0.5f64.to_bits(), (-0.0f64).to_bits(), f64::INFINITY.to_bits()],
                ),
            ],
            syncs: vec![(
                [1, 8, 1, 0, 2, 4, 1, 0, 1, 1],
                [0.1f64.to_bits(), 0.2f64.to_bits(), 0.0f64.to_bits()],
            )],
        }
    }

    fn write_one_scope() -> String {
        let mut w = WarmWriter::new();
        w.memo_scope(0xabcd, &rows(), &meta());
        w.out
    }

    #[test]
    fn memo_scope_roundtrips_bit_exactly() {
        let text = write_one_scope();
        let set = read_warm(&text, &GpuCatalog::builtin(), &meta());
        assert_eq!(set.scopes_rejected, 0);
        assert_eq!(set.memo_scopes.len(), 1);
        let (key, got) = &set.memo_scopes[0];
        assert_eq!(*key, 0xabcd);
        assert_eq!(got.stages, rows().stages, "stage rows must restore bit-exactly");
        assert_eq!(got.syncs, rows().syncs);
    }

    #[test]
    fn mismatched_identity_rejects_scope() {
        let text = write_one_scope();
        for bad in [
            EngineMeta { catalog: 0x9999, ..meta() },
            EngineMeta { eta: "forests:0000000000000000".to_string(), ..meta() },
            EngineMeta { consts: 0x9999, ..meta() },
            EngineMeta { book: 0x9999, ..meta() },
        ] {
            let set = read_warm(&text, &GpuCatalog::builtin(), &bad);
            assert!(set.memo_scopes.is_empty(), "mismatch must not import");
            assert_eq!(set.scopes_rejected, 1);
        }
    }

    #[test]
    fn tampered_value_fails_the_checksum() {
        let text = write_one_scope();
        // 1.5 = 0x3ff8000000000000; flip the low nibble of its row value.
        let tampered = text.replace("3ff8000000000000", "3ff8000000000001");
        assert_ne!(text, tampered, "tamper target missing from transcript");
        let set = read_warm(&tampered, &GpuCatalog::builtin(), &meta());
        assert!(set.memo_scopes.is_empty(), "bit flip must reject the scope");
        assert_eq!(set.scopes_rejected, 1);
    }

    #[test]
    fn truncated_and_garbage_files_degrade_not_error() {
        let text = write_one_scope();
        // Cut mid-rows.
        let cut: String = text.lines().take(3).map(|l| format!("{l}\n")).collect();
        let set = read_warm(&cut, &GpuCatalog::builtin(), &meta());
        assert!(set.memo_scopes.is_empty());
        assert!(set.scopes_rejected >= 1);
        // Unsupported version.
        let v2 = text.replace("{\"astra_warm\":1}", "{\"astra_warm\":2}");
        let set = read_warm(&v2, &GpuCatalog::builtin(), &meta());
        assert!(set.memo_scopes.is_empty());
        // Plain garbage.
        let set = read_warm("not a snapshot\nat all\n", &GpuCatalog::builtin(), &meta());
        assert!(set.memo_scopes.is_empty());
        assert_eq!(set.scopes_rejected, 1);
        // Empty file.
        let set = read_warm("", &GpuCatalog::builtin(), &meta());
        assert!(set.memo_scopes.is_empty());
    }

    #[test]
    fn second_scope_survives_a_rejected_first() {
        let mut w = WarmWriter::new();
        w.memo_scope(0x1, &rows(), &meta());
        w.memo_scope(0x2, &rows(), &meta());
        // Tamper only the first scope's footer checksum.
        let text = w.out;
        let mut lines: Vec<String> = text.lines().map(String::from).collect();
        let first_footer = lines.iter().position(|l| l.contains("\"end\"")).unwrap();
        lines[first_footer] = lines[first_footer].replace("\"sum\":\"", "\"sum\":\"f");
        // Keep the 16-digit width: drop the last checksum digit.
        let l = &mut lines[first_footer];
        let pos = l.rfind('"').unwrap();
        l.remove(pos - 1);
        let tampered = lines.join("\n") + "\n";
        let set = read_warm(&tampered, &GpuCatalog::builtin(), &meta());
        assert_eq!(set.scopes_rejected, 1);
        assert_eq!(set.memo_scopes.len(), 1, "clean second scope must still restore");
        assert_eq!(set.memo_scopes[0].0, 0x2);
    }

    #[test]
    fn inspect_reports_header_validity() {
        let mut w = WarmWriter::new();
        w.memo_scope(0xabcd, &rows(), &meta());
        let text = w.out;
        let ok = inspect(&text, &meta());
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].kind, "memo");
        assert_eq!(ok[0].rows, 3);
        assert_eq!(ok[0].status, "ok");
        let bad = inspect(&text, &EngineMeta { consts: 0x9999, ..meta() });
        assert_eq!(bad[0].status, "cost-consts digest mismatch");
    }

    fn sample_report(catalog: &GpuCatalog) -> SearchReport {
        let strategy = ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(catalog.find("a800").unwrap(), 4, 8),
            tp: 2,
            dp: 8,
            micro_batch: 2,
            global_batch: 512,
            vpp: 1,
            sequence_parallel: true,
            use_distributed_optimizer: true,
            recompute: Recompute::Full,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 4,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: false,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        };
        let cost = CostBreakdown {
            stage_times: vec![StageTime { fwd: 0.125, bwd: 0.33333333333333337, p2p: 1e-6 }],
            pipeline_fwd: 0.1,
            pipeline_bwd: 0.2,
            dp_time: 0.05,
            optimizer_time: 0.01,
            offload_time: 0.0,
            step_time: 0.36,
            tokens_per_s: 123456.789,
            mfu: 0.4321,
        };
        SearchReport {
            generated: 100,
            rule_filtered: 40,
            mem_filtered: 10,
            scored: 50,
            pruned_pools: 3,
            pruned_budget: 2,
            pruned_dominated: 1,
            search_secs: 0.123456789,
            simulate_secs: 0.987654321,
            phases: PhaseBreakdown {
                compile_secs: 0.001,
                speculate_secs: 0.002,
                expand_rules_secs: 0.1,
                mem_filter_secs: 0.02,
                score_secs: 0.5,
                hlo_pack_secs: 0.25,
            },
            memo_hits: 42,
            memo_misses: 7,
            top: vec![ScoredStrategy { strategy, cost, money_usd: 1234.5678 }],
            pool: OptimalPool::from_entries(vec![PoolEntry {
                idx: 0,
                throughput: 123456.789,
                cost: 1234.5678,
            }]),
            frontier: None,
            audit: None,
        }
    }

    /// [`sample_report`] with a one-candidate frontier skeleton attached,
    /// as a frontier-mode search would produce.
    fn sample_frontier_report(catalog: &GpuCatalog) -> SearchReport {
        let mut r = sample_report(catalog);
        let scored = r.top[0].clone();
        r.frontier = Some(FrontierReport {
            candidates: vec![FrontierCandidate { idx: 0, scored }],
        });
        r
    }

    /// [`sample_report`] with a small but feature-complete audit attached:
    /// every decision variant, non-finite bounds, a funnel, a wave record
    /// and winner/runner-up margins.
    fn sample_audited_report(catalog: &GpuCatalog) -> SearchReport {
        let mut r = sample_report(catalog);
        let winner = AuditContender {
            summary: "tp2 dp8 mb2".to_string(),
            step_time_s: 0.36,
            tokens_per_s: 123456.789,
            money_usd: 1234.5678,
        };
        let runner_up = AuditContender {
            summary: "tp4 dp4 mb1".to_string(),
            step_time_s: 0.375,
            tokens_per_s: 118519.0,
            money_usd: 1100.25,
        };
        r.audit = Some(SearchAudit {
            rounds: vec![AuditRound {
                round: 0,
                total: 32,
                pools: vec![
                    AuditPool {
                        pool: 0,
                        gpus: vec![("a800".to_string(), 32)],
                        tp: 2,
                        dp: 8,
                        ub_tput: f64::INFINITY,
                        lb_usd: 0.0,
                        decision: AuditDecision::Admitted,
                        funnel: Some(AuditFunnel {
                            expanded: 100,
                            rules_rejected: 40,
                            mem_rejected: 10,
                            scored: 50,
                            memo_hits: 42,
                            memo_misses: 7,
                        }),
                    },
                    AuditPool {
                        pool: 1,
                        gpus: vec![("h100".to_string(), 16), ("v100".to_string(), 16)],
                        tp: 4,
                        dp: 4,
                        ub_tput: 2e5,
                        lb_usd: 9001.5,
                        decision: AuditDecision::PrunedBudget { lb_usd: 9001.5, budget: 5000.0 },
                        funnel: None,
                    },
                    AuditPool {
                        pool: 2,
                        gpus: vec![("v100".to_string(), 32)],
                        tp: 1,
                        dp: 16,
                        ub_tput: 9e4,
                        lb_usd: 800.0,
                        decision: AuditDecision::PrunedDominated { by: (123456.789, 700.0) },
                        funnel: None,
                    },
                ],
            }],
            waves: vec![AuditWave { wave: 0, rounds: 1, speculated: 2, wasted: 1 }],
            margins: Some(AuditMargins {
                winner,
                runner_up: Some(runner_up),
                step_time_margin_s: 0.015,
                tokens_per_s_margin: 4937.789,
                money_margin_usd: 134.3178,
            }),
        });
        r
    }

    #[test]
    fn report_codec_roundtrips_bit_exactly() {
        let catalog = GpuCatalog::builtin();
        let r = sample_report(&catalog);
        let encoded = json::to_string(&report_to_value(&r, &catalog));
        let back = report_from_value(&json::parse(&encoded).unwrap(), &catalog).unwrap();
        assert_eq!(back.generated, r.generated);
        assert_eq!(back.pruned_pools, r.pruned_pools);
        assert_eq!(back.search_secs.to_bits(), r.search_secs.to_bits());
        assert_eq!(back.phases, r.phases);
        assert_eq!(back.phases.score_secs.to_bits(), r.phases.score_secs.to_bits());
        assert_eq!((back.memo_hits, back.memo_misses), (r.memo_hits, r.memo_misses));
        assert_eq!(back.top.len(), 1);
        assert_eq!(back.top[0].strategy, r.top[0].strategy);
        assert_eq!(back.top[0].money_usd.to_bits(), r.top[0].money_usd.to_bits());
        assert_eq!(
            back.top[0].cost.step_time.to_bits(),
            r.top[0].cost.step_time.to_bits()
        );
        assert_eq!(
            back.top[0].cost.stage_times[0].bwd.to_bits(),
            r.top[0].cost.stage_times[0].bwd.to_bits()
        );
        assert_eq!(back.pool.entries(), r.pool.entries());
        // And the canonical result view agrees byte-for-byte.
        assert_eq!(
            json::to_string(&crate::report::report_json(&back, &catalog)),
            json::to_string(&crate::report::report_json(&r, &catalog)),
        );
    }

    #[test]
    fn report_codec_accepts_snapshots_without_phases() {
        // Format-v1 snapshots written before the phase breakdown existed
        // must still restore; the breakdown comes back all-zero.
        let catalog = GpuCatalog::builtin();
        let r = sample_report(&catalog);
        let mut v = report_to_value(&r, &catalog);
        if let Value::Obj(m) = &mut v {
            m.remove("phases");
        }
        let back = report_from_value(&v, &catalog).unwrap();
        assert_eq!(back.phases, PhaseBreakdown::default());
        assert_eq!(back.search_secs.to_bits(), r.search_secs.to_bits());
    }

    #[test]
    fn cache_section_roundtrips_through_the_file() {
        let catalog = GpuCatalog::builtin();
        let r = sample_report(&catalog);
        let mut w = WarmWriter::new();
        w.cache_section(&[(0xfeed, Arc::new(sample_report(&catalog)))], &catalog, &meta());
        let set = read_warm(&w.out, &catalog, &meta());
        assert_eq!(set.scopes_rejected, 0);
        assert_eq!(set.cache.len(), 1);
        assert_eq!(set.cache[0].0, 0xfeed);
        assert_eq!(
            json::to_string(&report_to_value(&set.cache[0].1, &catalog)),
            json::to_string(&report_to_value(&r, &catalog)),
        );
        // A mismatched identity skips the cache section too.
        let set = read_warm(&w.out, &catalog, &EngineMeta { book: 0x9999, ..meta() });
        assert!(set.cache.is_empty());
        assert_eq!(set.scopes_rejected, 1);
    }

    #[test]
    fn frontier_codec_roundtrips_bit_exactly() {
        let catalog = GpuCatalog::builtin();
        let r = sample_frontier_report(&catalog);
        let encoded = json::to_string(&report_to_value(&r, &catalog));
        let back = report_from_value(&json::parse(&encoded).unwrap(), &catalog).unwrap();
        let (fa, fb) = (r.frontier.as_ref().unwrap(), back.frontier.as_ref().unwrap());
        assert_eq!(fa.candidates.len(), fb.candidates.len());
        assert_eq!(fa.candidates[0].idx, fb.candidates[0].idx);
        assert_eq!(fa.candidates[0].scored.strategy, fb.candidates[0].scored.strategy);
        assert_eq!(
            fa.candidates[0].scored.money_usd.to_bits(),
            fb.candidates[0].scored.money_usd.to_bits()
        );
        assert_eq!(
            fa.candidates[0].scored.cost.step_time.to_bits(),
            fb.candidates[0].scored.cost.step_time.to_bits()
        );
        // Frontier-free reports encode without the field and restore None.
        let plain = sample_report(&catalog);
        let encoded = json::to_string(&report_to_value(&plain, &catalog));
        assert!(!encoded.contains("\"frontier\""));
        let back = report_from_value(&json::parse(&encoded).unwrap(), &catalog).unwrap();
        assert!(back.frontier.is_none());
    }

    #[test]
    fn audit_codec_roundtrips_bit_exactly() {
        let catalog = GpuCatalog::builtin();
        let r = sample_audited_report(&catalog);
        let encoded = json::to_string(&report_to_value(&r, &catalog));
        let back = report_from_value(&json::parse(&encoded).unwrap(), &catalog).unwrap();
        // Struct-level equality covers decisions, evidence, funnels, waves
        // and margins in one shot...
        assert_eq!(back.audit, r.audit);
        // ...and spot-check bit patterns where `==` would also accept a
        // lossy decimal roundtrip (incl. the non-finite `ub_tput`).
        let (pa, pb) = (
            &r.audit.as_ref().unwrap().rounds[0].pools[0],
            &back.audit.as_ref().unwrap().rounds[0].pools[0],
        );
        assert_eq!(pa.ub_tput.to_bits(), pb.ub_tput.to_bits());
        assert!(pb.ub_tput.is_infinite());
        let (ma, mb) = (
            r.audit.as_ref().unwrap().margins.as_ref().unwrap(),
            back.audit.as_ref().unwrap().margins.as_ref().unwrap(),
        );
        assert_eq!(ma.tokens_per_s_margin.to_bits(), mb.tokens_per_s_margin.to_bits());
        assert_eq!(
            ma.runner_up.as_ref().unwrap().money_usd.to_bits(),
            mb.runner_up.as_ref().unwrap().money_usd.to_bits()
        );
        // The prune-reason split rides in the same row.
        assert_eq!((back.pruned_budget, back.pruned_dominated), (2, 1));
        // And a second encode of the restored struct is byte-identical:
        // what the cache serves after a restart is what it served before.
        assert_eq!(json::to_string(&report_to_value(&back, &catalog)), encoded);
    }

    #[test]
    fn audit_free_reports_encode_without_the_key_and_restore_none() {
        let catalog = GpuCatalog::builtin();
        let plain = sample_report(&catalog);
        let encoded = json::to_string(&report_to_value(&plain, &catalog));
        assert!(!encoded.contains("\"audit\""));
        let back = report_from_value(&json::parse(&encoded).unwrap(), &catalog).unwrap();
        assert!(back.audit.is_none());
    }

    #[test]
    fn report_codec_accepts_snapshots_without_pruned_split() {
        // Snapshots written before the pruned_budget/pruned_dominated split
        // existed restore with zeros; the total stays exact.
        let catalog = GpuCatalog::builtin();
        let r = sample_report(&catalog);
        let mut v = report_to_value(&r, &catalog);
        if let Value::Obj(m) = &mut v {
            m.remove("pruned_budget");
            m.remove("pruned_dominated");
        }
        let back = report_from_value(&v, &catalog).unwrap();
        assert_eq!(back.pruned_pools, 3);
        assert_eq!((back.pruned_budget, back.pruned_dominated), (0, 0));
    }

    #[test]
    fn frontier_cache_section_pins_membership_not_rates() {
        let catalog = GpuCatalog::builtin();
        let book_a = PriceBook::builtin();
        let meta_for = |book: &PriceBook| EngineMeta {
            book: book_digest(book),
            book_membership: book_membership_digest(book),
            ..meta()
        };
        let mut w = WarmWriter::new();
        w.frontier_cache_section(
            &[(0xf00d, Arc::new(sample_frontier_report(&catalog)))],
            &catalog,
            &meta_for(&book_a),
        );
        let text = w.out;

        // Rate-only edits (price move, spot billing, time-of-day) keep the
        // spilled frontier restorable: it is re-priced at serve time.
        let mut rates = book_a.clone();
        rates.upsert(PriceEntry {
            gpu: "h100".to_string(),
            on_demand_per_hour: 9.99,
            spot_per_hour: 3.33,
        });
        rates.use_spot = true;
        rates.hour = Some(3);
        assert_ne!(book_digest(&book_a), book_digest(&rates));
        let set = read_warm(&text, &catalog, &meta_for(&rates));
        assert_eq!(set.scopes_rejected, 0);
        assert_eq!(set.cache.len(), 1);
        assert_eq!(set.cache[0].0, 0xf00d);
        assert!(set.cache[0].1.frontier.is_some());

        // A membership change (new rate card) invalidates the section:
        // the frontier's candidate set could differ under the new book.
        let mut grown = book_a.clone();
        grown.upsert(PriceEntry {
            gpu: "tpu-v9".to_string(),
            on_demand_per_hour: 7.0,
            spot_per_hour: 2.8,
        });
        let set = read_warm(&text, &catalog, &meta_for(&grown));
        assert!(set.cache.is_empty(), "membership change must not restore");
        assert_eq!(set.scopes_rejected, 1);

        // And the ordinary cache section still pins the *full* book: the
        // same rate-only edit rejects it.
        let mut w = WarmWriter::new();
        w.cache_section(&[(0xbeef, Arc::new(sample_report(&catalog)))], &catalog, &meta_for(&book_a));
        let set = read_warm(&w.out, &catalog, &meta_for(&rates));
        assert!(set.cache.is_empty());
        assert_eq!(set.scopes_rejected, 1);
    }

    #[test]
    fn digests_discriminate() {
        let catalog = GpuCatalog::builtin();
        let d = catalog_digest(&catalog);
        let mut other = catalog.clone();
        other.gpus_per_node = 16;
        assert_ne!(d, catalog_digest(&other));

        let consts = CostConsts::default();
        let mut c2 = consts.clone();
        c2.tp_hide += 0.01;
        assert_ne!(consts_digest(&consts), consts_digest(&c2));

        let book = PriceBook::builtin();
        let mut spot = book.clone();
        spot.use_spot = true;
        assert_ne!(book_digest(&book), book_digest(&spot));

        assert_eq!(eta_identity(&EtaProvider::Analytic), "analytic");
        let f = crate::gbdt::EtaForests::new(Forest::constant(0.5, 4), Forest::constant(0.6, 4));
        let id = eta_identity(&EtaProvider::Forests(f));
        assert!(id.starts_with("forests:"), "{id}");
    }
}
