//! Transformer architecture registry and analytic model parameters.
//!
//! The paper evaluates seven model settings: Llama-2 (7B/13B/70B),
//! Llama-3 (8B/70B) and GLM (67B/130B). [`ModelSpec`] records the
//! architecture dimensions (§3.2 "model architecture parsing", Eq. 5–6) and
//! provides parameter/FLOP analytics consumed by the memory and cost models.
//!
//! GLM-67B's public config is not fully documented; we use a plausible
//! ChatGLM-2-lineage shape (documented in DESIGN.md §3) — only its *scale*
//! matters for reproducing the evaluation shapes.

use crate::{AstraError, Result};

/// Architecture of one training model (decoder-only transformer).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// KV heads (GQA); == heads for classic MHA.
    pub kv_heads: usize,
    /// MLP inner size (per expert for MoE models).
    pub ffn: usize,
    pub vocab: usize,
    /// Training sequence length.
    pub seq_len: usize,
    /// Default global batch in sequences (Megatron convention).
    pub global_batch: usize,
    /// Number of routed experts; 0 = dense model.
    pub num_experts: usize,
    /// Router top-k (experts activated per token); 0 for dense.
    pub moe_topk: usize,
}

impl ModelSpec {
    /// Parameters of one transformer layer.
    ///
    /// Attention: Q is `h·h`, K/V are `h·h·kv/heads` (GQA), output `h·h`.
    /// MLP: gated SwiGLU-style `3·h·ffn` for Llama, classic `2·h·ffn`
    /// otherwise — we model gated MLP whenever `ffn < 4h` (Llama family).
    pub fn layer_params(&self) -> f64 {
        let h = self.hidden as f64;
        let kv_frac = self.kv_heads as f64 / self.heads as f64;
        let attn = h * h * (2.0 + 2.0 * kv_frac); // Q,O + K,V
        let mlp_mats = if self.gated_mlp() { 3.0 } else { 2.0 };
        // MoE: every expert carries a full MLP, plus the router matrix.
        let expert_copies = self.num_experts.max(1) as f64;
        let router = if self.is_moe() { h * self.num_experts as f64 } else { 0.0 };
        let mlp = expert_copies * mlp_mats * h * self.ffn as f64 + router;
        let norms = 2.0 * h;
        attn + mlp + norms
    }

    /// True for mixture-of-experts models.
    pub fn is_moe(&self) -> bool {
        self.num_experts > 1
    }

    /// Active MLP copies per token (top-k for MoE, 1 for dense).
    pub fn active_mlp_factor(&self) -> f64 {
        if self.is_moe() {
            self.moe_topk.max(1) as f64
        } else {
            1.0
        }
    }

    /// Gated (SwiGLU) MLP heuristic: Llama-style ffn sizes are < 4h.
    pub fn gated_mlp(&self) -> bool {
        (self.ffn as f64) < 4.0 * self.hidden as f64
    }

    /// Embedding (+ tied LM head counted once) parameters.
    pub fn embedding_params(&self) -> f64 {
        self.vocab as f64 * self.hidden as f64
    }

    /// Total parameters (embedding + untied head + layers + final norm).
    pub fn total_params(&self) -> f64 {
        2.0 * self.embedding_params()
            + self.layers as f64 * self.layer_params()
            + self.hidden as f64
    }

    /// Forward FLOPs of one layer for a `(b, s)` microbatch (dense GEMMs
    /// only; each MAC = 2 flops).
    pub fn layer_fwd_flops(&self, batch: usize, seq: usize) -> f64 {
        let b = batch as f64;
        let s = seq as f64;
        let h = self.hidden as f64;
        let kv_frac = self.kv_heads as f64 / self.heads as f64;
        // QKVO projections.
        let proj = 2.0 * b * s * h * h * (2.0 + 2.0 * kv_frac);
        // Attention scores + context (full, causal halves it but Megatron
        // materializes full matmuls).
        let attn = 2.0 * b * s * s * h * 2.0;
        // MLP — MoE processes each token through top-k experts.
        let mlp_mats = if self.gated_mlp() { 3.0 } else { 2.0 };
        let mlp = 2.0 * b * s * h * self.ffn as f64 * mlp_mats * self.active_mlp_factor();
        proj + attn + mlp
    }

    /// Forward FLOPs of the LM head (vocab projection).
    pub fn head_fwd_flops(&self, batch: usize, seq: usize) -> f64 {
        2.0 * batch as f64 * seq as f64 * self.hidden as f64 * self.vocab as f64
    }

    /// Tokens in one global batch.
    pub fn tokens_per_batch(&self) -> f64 {
        (self.global_batch * self.seq_len) as f64
    }
}

/// Registry of known model settings.
#[derive(Debug, Clone)]
pub struct ModelRegistry {
    models: Vec<ModelSpec>,
}

impl ModelRegistry {
    pub fn builtin() -> Self {
        let mk = |name: &str,
                  layers: usize,
                  hidden: usize,
                  heads: usize,
                  kv_heads: usize,
                  ffn: usize,
                  vocab: usize,
                  seq: usize| ModelSpec {
            name: name.into(),
            layers,
            hidden,
            heads,
            kv_heads,
            ffn,
            vocab,
            seq_len: seq,
            global_batch: 2048,
            num_experts: 0,
            moe_topk: 0,
        };
        ModelRegistry {
            models: vec![
                mk("llama2-7b", 32, 4096, 32, 32, 11008, 32000, 4096),
                mk("llama2-13b", 40, 5120, 40, 40, 13824, 32000, 4096),
                mk("llama2-70b", 80, 8192, 64, 8, 28672, 32000, 4096),
                mk("llama3-8b", 32, 4096, 32, 8, 14336, 128256, 4096),
                mk("llama3-70b", 80, 8192, 64, 8, 28672, 128256, 4096),
                mk("glm-67b", 64, 9216, 72, 72, 24576, 65024, 4096),
                mk("glm-130b", 70, 12288, 96, 96, 32768, 150528, 2048),
                // MoE setting for the Table 3 MoE parameters (Mixtral-8x7B
                // shape: 8 experts, top-2 router).
                ModelSpec {
                    name: "mixtral-8x7b".into(),
                    layers: 32,
                    hidden: 4096,
                    heads: 32,
                    kv_heads: 8,
                    ffn: 14336,
                    vocab: 32000,
                    seq_len: 4096,
                    global_batch: 2048,
                    num_experts: 8,
                    moe_topk: 2,
                },
            ],
        }
    }

    pub fn get(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name.eq_ignore_ascii_case(name))
            .ok_or_else(|| {
                AstraError::Config(format!(
                    "unknown model '{name}' (known: {})",
                    self.models.iter().map(|m| m.name.as_str()).collect::<Vec<_>>().join(", ")
                ))
            })
    }

    pub fn all(&self) -> &[ModelSpec] {
        &self.models
    }

    /// The paper's seven evaluation settings, in its order.
    pub fn paper_seven(&self) -> Vec<&ModelSpec> {
        ["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
            .iter()
            .map(|n| self.get(n).unwrap())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_billing_names() {
        let reg = ModelRegistry::builtin();
        // Within 12% of the nominal size in the model's name.
        for (name, nominal_b) in [
            ("llama2-7b", 6.7e9),
            ("llama2-13b", 13.0e9),
            ("llama2-70b", 69.0e9),
            ("llama3-8b", 8.0e9),
            ("llama3-70b", 70.6e9),
            ("glm-67b", 67.0e9),
            ("glm-130b", 130.0e9),
        ] {
            let p = reg.get(name).unwrap().total_params();
            let rel = (p - nominal_b).abs() / nominal_b;
            assert!(rel < 0.12, "{name}: {p:.3e} vs nominal {nominal_b:.3e} (rel {rel:.3})");
        }
    }

    #[test]
    fn seven_paper_settings_present() {
        let reg = ModelRegistry::builtin();
        assert_eq!(reg.paper_seven().len(), 7);
    }

    #[test]
    fn gqa_reduces_params() {
        let reg = ModelRegistry::builtin();
        let l2 = reg.get("llama2-70b").unwrap();
        assert!(l2.kv_heads < l2.heads);
        let mut mha = l2.clone();
        mha.kv_heads = mha.heads;
        assert!(mha.layer_params() > l2.layer_params());
    }

    #[test]
    fn flops_scale_with_batch_and_seq() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let f1 = m.layer_fwd_flops(1, 4096);
        let f2 = m.layer_fwd_flops(2, 4096);
        assert!((f2 / f1 - 2.0).abs() < 1e-9);
        // Doubling seq more than doubles (quadratic attention term).
        let f4 = m.layer_fwd_flops(1, 8192);
        assert!(f4 / f1 > 2.0);
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(ModelRegistry::builtin().get("gpt-5").is_err());
    }

    #[test]
    fn megatron_6nd_sanity() {
        // Total fwd flops per token ≈ 2·params (the classic 6ND/3 rule,
        // ignoring attention quadratic term at moderate seq).
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let per_layer_tok = m.layer_fwd_flops(1, m.seq_len) / m.seq_len as f64;
        let expect = 2.0 * m.layer_params();
        let rel = (per_layer_tok - expect).abs() / expect;
        assert!(rel < 0.35, "per-token layer flops {per_layer_tok:.3e} vs 2P {expect:.3e}");
    }
}
