//! Benchmark harness substrate (criterion is not available offline).
//!
//! Provides warmup + timed iterations with robust statistics (mean, p50,
//! p95, min) plus ASCII/CSV reporting used by every `rust/benches/*` target.
//! Benches declare `harness = false` and drive this directly.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one timed measurement.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} iters={:<5} mean={:>12} p50={:>12} p95={:>12} min={:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            fmt_dur(self.min),
        )
    }
}

/// Human duration: picks ns/µs/ms/s.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Wall-clock budget for warmup.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Minimum measured iterations (even if over budget).
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // ASTRA_BENCH_FAST=1 slashes budgets for smoke runs / CI.
        if std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1") {
            BenchConfig {
                warmup: Duration::from_millis(50),
                measure: Duration::from_millis(200),
                max_iters: 30,
                min_iters: 3,
            }
        } else {
            BenchConfig {
                warmup: Duration::from_millis(300),
                measure: Duration::from_secs(2),
                max_iters: 1000,
                min_iters: 5,
            }
        }
    }
}

/// A collection of measurements, printable as a table.
#[derive(Default)]
pub struct Bench {
    pub config: BenchConfig,
    pub results: Vec<Stats>,
}

impl Bench {
    pub fn new() -> Self {
        Bench { config: BenchConfig::default(), results: Vec::new() }
    }

    /// Time `f` (its return value is black-boxed). Returns the stats and
    /// records them for the final table.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Stats {
        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.config.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let mstart = Instant::now();
        while (mstart.elapsed() < self.config.measure && samples.len() < self.config.max_iters)
            || samples.len() < self.config.min_iters
        {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
        };
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    /// Time a single invocation (for long end-to-end passes where iterating
    /// is pointless); still recorded in the table.
    pub fn run_once<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> (Stats, R) {
        let t = Instant::now();
        let out = black_box(f());
        let d = t.elapsed();
        let stats =
            Stats { name: name.to_string(), iters: 1, mean: d, p50: d, p95: d, min: d };
        println!("{stats}");
        self.results.push(stats.clone());
        (stats, out)
    }

    /// Dump results as CSV (for EXPERIMENTS.md extraction).
    pub fn csv(&self) -> String {
        let mut out = String::from("name,iters,mean_s,p50_s,p95_s,min_s\n");
        for s in &self.results {
            out.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9},{:.9}\n",
                s.name,
                s.iters,
                s.mean.as_secs_f64(),
                s.p50.as_secs_f64(),
                s.p95.as_secs_f64(),
                s.min.as_secs_f64()
            ));
        }
        out
    }
}

/// Print a bench section header (consistent look across all bench targets).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            config: BenchConfig {
                warmup: Duration::from_millis(1),
                measure: Duration::from_millis(10),
                max_iters: 50,
                min_iters: 3,
            },
            results: Vec::new(),
        };
        let s = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.iters >= 3);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
        assert!(b.csv().lines().count() == 2);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
