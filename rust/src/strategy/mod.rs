//! Parallel strategies and the search-space generator (paper §3.2–3.3).
//!
//! A [`ParallelStrategy`] is one point of the Megatron-LM parameter space
//! (Appendix Table 3) bound to a concrete cluster assignment — either a
//! single GPU type (homogeneous / cost modes) or a pipeline-ordered list of
//! GPU-type segments (heterogeneous mode, Eq. 23).
//!
//! The [`SearchSpace`] generator produces `S = f(P) × C_gpu` (Eq. 8–9); the
//! rule filter ([`crate::rules`]) and memory filter ([`crate::memory`])
//! subsequently narrow it to `S_valid` (Eq. 21).

mod space;

pub use space::{SearchSpace, SpaceConfig};

use crate::gpu::GpuType;
use crate::model::ModelSpec;
use crate::rules::{FieldSource, Val};

/// Activation recomputation granularity (Megatron `--recompute-granularity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Recompute {
    /// No recomputation.
    None,
    /// Selective: recompute attention scores only.
    Selective,
    /// Full: recompute whole layers (`method`, `num_layers` apply).
    Full,
}

impl Recompute {
    pub fn as_str(&self) -> &'static str {
        match self {
            Recompute::None => "none",
            Recompute::Selective => "selective",
            Recompute::Full => "full",
        }
    }

    /// Inverse of [`Recompute::as_str`] (wire/persist decode).
    pub fn parse(s: &str) -> Option<Recompute> {
        match s {
            "none" => Some(Recompute::None),
            "selective" => Some(Recompute::Selective),
            "full" => Some(Recompute::Full),
            _ => None,
        }
    }
}

/// Megatron `--recompute-method` (only meaningful with [`Recompute::Full`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RecomputeMethod {
    Block,
    Uniform,
}

impl RecomputeMethod {
    pub fn as_str(&self) -> &'static str {
        match self {
            RecomputeMethod::Block => "block",
            RecomputeMethod::Uniform => "uniform",
        }
    }

    /// Inverse of [`RecomputeMethod::as_str`] (wire/persist decode).
    pub fn parse(s: &str) -> Option<RecomputeMethod> {
        match s {
            "block" => Some(RecomputeMethod::Block),
            "uniform" => Some(RecomputeMethod::Uniform),
            _ => None,
        }
    }
}

/// One pipeline-contiguous run of stages on a single GPU type
/// (heterogeneous partitions rearrange equal types contiguously — §3.4).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Segment {
    pub gpu: GpuType,
    /// Number of pipeline stages in this segment (`m_i`).
    pub stages: usize,
    /// Model layers per stage in this segment (`n_i`).
    pub layers_per_stage: usize,
}

/// Cluster assignment of a strategy.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ClusterAssignment {
    /// Pipeline-ordered GPU segments; homogeneous = a single segment.
    pub segments: Vec<Segment>,
}

impl ClusterAssignment {
    pub fn homogeneous(gpu: GpuType, pp: usize, layers_per_stage: usize) -> Self {
        ClusterAssignment { segments: vec![Segment { gpu, stages: pp, layers_per_stage }] }
    }

    /// Total pipeline stages `P`.
    pub fn pp(&self) -> usize {
        self.segments.iter().map(|s| s.stages).sum()
    }

    /// Total model layers covered.
    pub fn layers(&self) -> usize {
        self.segments.iter().map(|s| s.stages * s.layers_per_stage).sum()
    }

    pub fn is_heterogeneous(&self) -> bool {
        self.segments.len() > 1
    }

    /// GPU type of pipeline stage `i`.
    pub fn gpu_of_stage(&self, i: usize) -> GpuType {
        let mut idx = i;
        for seg in &self.segments {
            if idx < seg.stages {
                return seg.gpu;
            }
            idx -= seg.stages;
        }
        panic!("stage {i} out of range (pp={})", self.pp());
    }

    /// Layers in pipeline stage `i`.
    pub fn layers_of_stage(&self, i: usize) -> usize {
        let mut idx = i;
        for seg in &self.segments {
            if idx < seg.stages {
                return seg.layers_per_stage;
            }
            idx -= seg.stages;
        }
        panic!("stage {i} out of range (pp={})", self.pp());
    }

    /// GPUs of each type consumed given `tp`/`dp`: `m_i · tp · dp`.
    pub fn gpus_by_type(&self, tp: usize, dp: usize) -> Vec<(GpuType, usize)> {
        let mut acc: Vec<(GpuType, usize)> = Vec::new();
        for seg in &self.segments {
            let n = seg.stages * tp * dp;
            match acc.iter_mut().find(|(g, _)| *g == seg.gpu) {
                Some((_, c)) => *c += n,
                None => acc.push((seg.gpu, n)),
            }
        }
        acc
    }
}

/// One hybrid parallel strategy: the Megatron parameter point (Table 3)
/// plus its cluster assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelStrategy {
    pub cluster: ClusterAssignment,
    /// Tensor-model-parallel size.
    pub tp: usize,
    /// Data-parallel size.
    pub dp: usize,
    /// Micro-batch size (sequences).
    pub micro_batch: usize,
    /// Global batch (sequences) — workload parameter, copied from the model.
    pub global_batch: usize,
    /// Interleaving degree (virtual pipeline chunks per stage); 1 = off.
    pub vpp: usize,
    pub sequence_parallel: bool,
    pub use_distributed_optimizer: bool,
    pub recompute: Recompute,
    pub recompute_method: RecomputeMethod,
    /// Layers recomputed per stage under [`Recompute::Full`].
    pub recompute_num_layers: usize,
    pub offload_optimizer: bool,
    /// Overlap strategies (paper Table 3 fixes these `true`; the Fig. 11
    /// ablation toggles them).
    pub overlap_grad_reduce: bool,
    pub overlap_param_gather: bool,
    pub overlap_p2p: bool,
    pub tp_comm_overlap: bool,
    pub use_flash_attn: bool,
    /// Expert-model-parallel size (Table 3 MoE parameter); 1 for dense.
    pub ep: usize,
}

impl ParallelStrategy {
    /// Pipeline-parallel size `P`.
    pub fn pp(&self) -> usize {
        self.cluster.pp()
    }

    /// Total GPUs consumed: `pp · tp · dp`.
    pub fn num_gpus(&self) -> usize {
        self.pp() * self.tp * self.dp
    }

    /// Number of microbatches `K = gbs / (dp · mbs)`.
    pub fn num_microbatches(&self) -> usize {
        self.global_batch / (self.dp * self.micro_batch)
    }

    /// Structural validity (the generator only emits valid strategies;
    /// this is re-checked by tests and on config-loaded strategies).
    pub fn validate(&self, model: &ModelSpec) -> crate::Result<()> {
        let fail = |m: String| Err(crate::AstraError::Config(m));
        if self.tp == 0 || self.dp == 0 || self.pp() == 0 || self.micro_batch == 0 {
            return fail("zero-sized parallel dim".into());
        }
        if model.heads % self.tp != 0 {
            return fail(format!("heads {} not divisible by tp {}", model.heads, self.tp));
        }
        if self.cluster.layers() != model.layers {
            return fail(format!(
                "stage layers {} != model layers {}",
                self.cluster.layers(),
                model.layers
            ));
        }
        if self.global_batch % (self.dp * self.micro_batch) != 0 {
            return fail(format!(
                "gbs {} not divisible by dp·mbs {}",
                self.global_batch,
                self.dp * self.micro_batch
            ));
        }
        if self.sequence_parallel && self.tp == 1 {
            return fail("sequence parallel requires tp > 1".into());
        }
        if self.vpp > 1 {
            if self.pp() == 1 {
                return fail("interleaving requires pp > 1".into());
            }
            // every stage's layer count must split into vpp chunks
            for seg in &self.cluster.segments {
                if seg.layers_per_stage % self.vpp != 0 {
                    return fail(format!(
                        "layers/stage {} not divisible by vpp {}",
                        seg.layers_per_stage, self.vpp
                    ));
                }
            }
        }
        if model.is_moe() {
            if self.ep == 0 || model.num_experts % self.ep != 0 {
                return fail(format!(
                    "experts {} not divisible by ep {}",
                    model.num_experts, self.ep
                ));
            }
            // Megatron carves the expert-parallel group out of DP.
            if self.dp % self.ep != 0 {
                return fail(format!("dp {} not divisible by ep {}", self.dp, self.ep));
            }
        } else if self.ep != 1 {
            return fail("ep > 1 on a dense model".into());
        }
        if self.recompute == Recompute::Full {
            let max_lps =
                self.cluster.segments.iter().map(|s| s.layers_per_stage).max().unwrap_or(0);
            if self.recompute_num_layers == 0 || self.recompute_num_layers > max_lps {
                return fail(format!(
                    "recompute_num_layers {} outside 1..={max_lps}",
                    self.recompute_num_layers
                ));
            }
        }
        Ok(())
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let seg = if self.cluster.is_heterogeneous() {
            let parts: Vec<String> = self
                .cluster
                .segments
                .iter()
                .map(|s| format!("g{}×{}({}L)", s.gpu, s.stages, s.layers_per_stage))
                .collect();
            format!(" segs=[{}]", parts.join(","))
        } else {
            String::new()
        };
        let ep = if self.ep > 1 { format!(" ep={}", self.ep) } else { String::new() };
        format!(
            "tp={} pp={} dp={} mbs={} vpp={} sp={} do={} rc={}/{}/{} off={} gpus={}{ep}{}",
            self.tp,
            self.pp(),
            self.dp,
            self.micro_batch,
            self.vpp,
            self.sequence_parallel as u8,
            self.use_distributed_optimizer as u8,
            self.recompute.as_str(),
            self.recompute_method.as_str(),
            self.recompute_num_layers,
            self.offload_optimizer as u8,
            self.num_gpus(),
            seg
        )
    }
}

/// `$field` resolution for the rule DSL — names follow Megatron flags.
impl FieldSource for ParallelStrategy {
    fn field(&self, name: &str) -> Option<Val> {
        Some(match name {
            "tensor_model_parallel_size" | "tp" => Val::Int(self.tp as i64),
            "pipeline_model_parallel_size" | "pp" => Val::Int(self.pp() as i64),
            "data_model_parallel_size" | "data_parallel_size" | "dp" => Val::Int(self.dp as i64),
            "micro_batch_size" | "mbs" => Val::Int(self.micro_batch as i64),
            "global_batch_size" | "gbs" => Val::Int(self.global_batch as i64),
            "num_microbatches" => Val::Int(self.num_microbatches() as i64),
            "virtual_pipeline_parallel_size" | "vpp" => Val::Int(self.vpp as i64),
            "num_gpus" => Val::Int(self.num_gpus() as i64),
            "sequence_parallel" => Val::Bool(self.sequence_parallel),
            "use_distributed_optimizer" => Val::Bool(self.use_distributed_optimizer),
            "recompute_granularity" => match self.recompute {
                Recompute::None => Val::None,
                g => Val::Sym(g.as_str().to_string()),
            },
            "recompute_method" => Val::Sym(self.recompute_method.as_str().to_string()),
            "recompute_num_layers" => Val::Int(self.recompute_num_layers as i64),
            "offload_optimizer" => Val::Bool(self.offload_optimizer),
            "no_overlap_offload_optimizer" => Val::Bool(!self.offload_optimizer),
            "overlap_grad_reduce" => Val::Bool(self.overlap_grad_reduce),
            "overlap_param_gather" => Val::Bool(self.overlap_param_gather),
            "overlap_p2p_communication" => Val::Bool(self.overlap_p2p),
            "tp_comm_overlap" => Val::Bool(self.tp_comm_overlap),
            "expert_model_parallel_size" | "ep" => Val::Int(self.ep as i64),
            "use_flash_attn" => {
                if self.use_flash_attn {
                    Val::Bool(true)
                } else {
                    Val::None
                }
            }
            _ => return None,
        })
    }
}

/// The GPU-pool input modes of §3.2 (Eq. 1–3), plus the heterogeneous
/// money-saving extension.
#[derive(Debug, Clone, PartialEq)]
pub enum GpuPoolMode {
    /// Mode 1: one GPU type, fixed count.
    Homogeneous { gpu: GpuType, count: usize },
    /// Mode 2: total cluster size + per-type maximum counts.
    Heterogeneous { total: usize, caps: Vec<(GpuType, usize)> },
    /// Mode 3: one GPU type, count swept up to `max_count`, with a money
    /// ceiling applied at selection time.
    Cost { gpu: GpuType, max_count: usize, max_money: f64 },
    /// Mode 3 over mixed pools: total cluster sizes are swept under
    /// per-type caps (as in mode 2), each candidate is priced per type per
    /// hour through the [`crate::pricing::PriceBook`], and a money ceiling
    /// prunes and selects (§3.6 fused with §3.4).
    HeteroCost { caps: Vec<(GpuType, usize)>, max_money: f64 },
    /// The hetero-cost sweep with no budget and no money pruning: every
    /// pool is scored and the *full* (throughput, USD) Pareto frontier is
    /// the result, carried as a reprice skeleton so a cached frontier can
    /// be re-billed under a new price book without re-searching (see
    /// [`crate::pareto`] module docs).
    Frontier { caps: Vec<(GpuType, usize)> },
}

/// Canonicalize per-type capacity entries as a *map*: duplicate keys merge
/// by summation, first-seen order preserved. The single definition behind
/// the request constructor, the service fingerprint, and the wire
/// serialization — these must agree exactly or cache keys drift.
pub fn merge_caps<K: PartialEq>(entries: impl IntoIterator<Item = (K, usize)>) -> Vec<(K, usize)> {
    let mut out: Vec<(K, usize)> = Vec::new();
    for (k, c) in entries {
        match out.iter_mut().find(|(g, _)| *g == k) {
            Some((_, acc)) => *acc += c,
            None => out.push((k, c)),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelRegistry;

    pub(crate) fn base_strategy(model: &ModelSpec, gpu: GpuType, tp: usize, pp: usize, dp: usize) -> ParallelStrategy {
        ParallelStrategy {
            cluster: ClusterAssignment::homogeneous(gpu, pp, model.layers / pp),
            tp,
            dp,
            micro_batch: 1,
            global_batch: model.global_batch,
            vpp: 1,
            sequence_parallel: tp > 1,
            use_distributed_optimizer: true,
            recompute: Recompute::None,
            recompute_method: RecomputeMethod::Uniform,
            recompute_num_layers: 0,
            offload_optimizer: false,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_p2p: true,
            tp_comm_overlap: true,
            use_flash_attn: true,
            ep: 1,
        }
    }

    #[test]
    fn gpu_accounting() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = base_strategy(m, 0, 2, 4, 8);
        assert_eq!(s.num_gpus(), 64);
        assert_eq!(s.num_microbatches(), 2048 / 8);
        assert!(s.validate(m).is_ok());
    }

    #[test]
    fn hetero_stage_lookup() {
        let ca = ClusterAssignment {
            segments: vec![
                Segment { gpu: 2, stages: 2, layers_per_stage: 10 },
                Segment { gpu: 1, stages: 4, layers_per_stage: 15 },
            ],
        };
        assert_eq!(ca.pp(), 6);
        assert_eq!(ca.layers(), 80);
        assert_eq!(ca.gpu_of_stage(0), 2);
        assert_eq!(ca.gpu_of_stage(1), 2);
        assert_eq!(ca.gpu_of_stage(2), 1);
        assert_eq!(ca.layers_of_stage(5), 15);
        assert_eq!(ca.gpus_by_type(2, 3), vec![(2, 12), (1, 24)]);
    }

    #[test]
    fn validation_catches_bad_shapes() {
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap(); // 32 layers, 32 heads
        let mut s = base_strategy(m, 0, 2, 4, 8);
        s.tp = 3; // heads % 3 != 0
        assert!(s.validate(m).is_err());

        let mut s = base_strategy(m, 0, 2, 4, 8);
        s.cluster.segments[0].layers_per_stage = 7; // 4*7 != 32
        assert!(s.validate(m).is_err());

        let mut s = base_strategy(m, 0, 1, 4, 8);
        s.sequence_parallel = true; // sp with tp=1
        assert!(s.validate(m).is_err());

        let mut s = base_strategy(m, 0, 2, 1, 8);
        s.vpp = 2; // vpp with pp=1
        assert!(s.validate(m).is_err());
    }

    #[test]
    fn merge_caps_sums_duplicates_in_order() {
        assert_eq!(
            merge_caps(vec![("a", 16), ("b", 8), ("a", 16)]),
            vec![("a", 32), ("b", 8)]
        );
        assert_eq!(merge_caps(Vec::<(usize, usize)>::new()), vec![]);
    }

    #[test]
    fn rule_field_bridge() {
        use crate::rules::RuleSet;
        let reg = ModelRegistry::builtin();
        let m = reg.get("llama2-7b").unwrap();
        let s = base_strategy(m, 0, 2, 4, 8);
        let rs = RuleSet::paper_defaults();
        assert!(!rs.filters_out(&s).unwrap());

        // recompute selective + flash ⇒ filtered by paper rule 1
        let mut bad = s.clone();
        bad.recompute = Recompute::Selective;
        assert!(rs.filters_out(&bad).unwrap());
    }
}
