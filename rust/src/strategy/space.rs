//! Search-space generator (paper §3.3, Eq. 8–9).
//!
//! Generates the raw cross-product `S = f(P) × C_gpu` of parameter options
//! for a given model and GPU configuration. Filtering (rules + memory) is
//! applied downstream by the coordinator, matching the paper's pipeline —
//! so the `#Strategies` this module reports corresponds to Table 1's
//! search-space column.

use super::{ClusterAssignment, ParallelStrategy, Recompute, RecomputeMethod};
use crate::gpu::{GpuCatalog, GpuType};
use crate::model::ModelSpec;

/// Which parameter values the generator may use (Appendix Table 3 ranges).
/// Ablation benches narrow these (e.g. Fig. 8 forces DP-only).
#[derive(Debug, Clone)]
pub struct SpaceConfig {
    /// Tensor-parallel sizes to try (additionally constrained to divide the
    /// head count and to fit inside one node).
    pub tp_candidates: Vec<usize>,
    /// Upper bound on pipeline-parallel size.
    pub max_pp: usize,
    /// Micro-batch sizes to try.
    pub mbs_candidates: Vec<usize>,
    /// Interleaving degrees to try (1 = off).
    pub vpp_candidates: Vec<usize>,
    pub seq_parallel_options: Vec<bool>,
    pub dist_opt_options: Vec<bool>,
    pub offload_options: Vec<bool>,
    /// Include `recompute-granularity = none / selective / full` variants.
    pub recompute_none: bool,
    pub recompute_selective: bool,
    pub recompute_full: bool,
    /// Overlap flags value (paper fixes them `true`; Fig. 11 flips to false).
    pub overlap: bool,
    /// `use-flash-attn` (Table 3 range is `[true]`).
    pub use_flash_attn: bool,
    /// Expert-model-parallel sizes to try on MoE models (Table 3).
    pub ep_candidates: Vec<usize>,
}

impl Default for SpaceConfig {
    fn default() -> Self {
        SpaceConfig {
            tp_candidates: vec![1, 2, 4, 8],
            max_pp: 64,
            mbs_candidates: vec![1, 2, 4, 8, 16],
            vpp_candidates: vec![1, 2, 4],
            seq_parallel_options: vec![false, true],
            dist_opt_options: vec![false, true],
            offload_options: vec![false, true],
            recompute_none: true,
            recompute_selective: true,
            recompute_full: true,
            overlap: true,
            use_flash_attn: true,
            ep_candidates: vec![1, 2, 4, 8],
        }
    }
}

impl SpaceConfig {
    /// Fig. 8 ablation: data parallelism only.
    pub fn dp_only() -> Self {
        SpaceConfig { tp_candidates: vec![1], max_pp: 1, ..Default::default() }
    }

    /// Fig. 11 ablation: all communication overlap off.
    pub fn no_overlap() -> Self {
        SpaceConfig { overlap: false, ..Default::default() }
    }

    /// Fig. 10 ablation: offload disallowed.
    pub fn no_offload() -> Self {
        SpaceConfig { offload_options: vec![false], ..Default::default() }
    }
}

/// The generator.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub config: SpaceConfig,
}

impl SearchSpace {
    pub fn new(config: SpaceConfig) -> Self {
        SearchSpace { config }
    }

    /// Enumerate the homogeneous search space for (`model`, `gpu` × `count`).
    ///
    /// Structural constraints applied here (they define the space, not the
    /// filters): `heads % tp == 0`, `tp ≤ gpus/node`, `layers % pp == 0`,
    /// `count % (tp·pp) == 0`, `gbs % (dp·mbs) == 0`, vpp divides
    /// layers/stage. Everything else (paper rules, memory) filters later.
    pub fn homogeneous(
        &self,
        model: &ModelSpec,
        catalog: &GpuCatalog,
        gpu: GpuType,
        count: usize,
    ) -> Vec<ParallelStrategy> {
        let mut out = Vec::new();
        for (cluster, tp, dp) in self.homogeneous_pools(model, catalog, gpu, count) {
            self.expand_params(model, &cluster, tp, dp, &mut out);
        }
        out
    }

    /// The `(cluster, tp, dp)` pools of the homogeneous space, in the same
    /// order [`Self::homogeneous`] generates them. This is the unit of the
    /// coordinator's streaming fan-out: a pool's parameter cross-product is
    /// expanded, filtered and scored in one per-worker pass, so the full
    /// candidate vector is never materialized. The two views share this one
    /// enumeration so they cannot drift.
    pub fn homogeneous_pools(
        &self,
        model: &ModelSpec,
        catalog: &GpuCatalog,
        gpu: GpuType,
        count: usize,
    ) -> Vec<(ClusterAssignment, usize, usize)> {
        let mut pools = Vec::new();
        for &tp in &self.valid_tps(model, catalog) {
            if count % tp != 0 {
                continue;
            }
            for pp in self.valid_pps(model, count, tp) {
                let dp = count / (tp * pp);
                let cluster = ClusterAssignment::homogeneous(gpu, pp, model.layers / pp);
                pools.push((cluster, tp, dp));
            }
        }
        pools
    }

    /// TP sizes valid for this model/topology.
    pub fn valid_tps(&self, model: &ModelSpec, catalog: &GpuCatalog) -> Vec<usize> {
        self.config
            .tp_candidates
            .iter()
            .copied()
            .filter(|&tp| tp <= catalog.gpus_per_node && model.heads % tp == 0)
            .collect()
    }

    /// PP sizes valid for this model and GPU count at a given TP.
    pub fn valid_pps(&self, model: &ModelSpec, count: usize, tp: usize) -> Vec<usize> {
        (1..=self.config.max_pp.min(model.layers).min(count / tp))
            .filter(|&pp| model.layers % pp == 0 && count % (tp * pp) == 0)
            .collect()
    }

    /// Cross-product of the per-strategy parameters for a fixed
    /// (cluster, tp, dp). Shared by the homogeneous and heterogeneous paths.
    pub fn expand_params(
        &self,
        model: &ModelSpec,
        cluster: &ClusterAssignment,
        tp: usize,
        dp: usize,
        out: &mut Vec<ParallelStrategy>,
    ) {
        self.expand_params_each(model, cluster, tp, dp, &mut |s| out.push(s));
    }

    /// Visitor form of [`Self::expand_params`]: hand each strategy to `f`
    /// as it is produced instead of collecting a vector. The coordinator's
    /// streaming pipeline fuses generation → rule filter → memory filter →
    /// scoring inside the visitor, which is what keeps the hot path free of
    /// per-round candidate-vector allocation. Emission order is identical
    /// to the collected form (the two are literally the same loop).
    pub fn expand_params_each(
        &self,
        model: &ModelSpec,
        cluster: &ClusterAssignment,
        tp: usize,
        dp: usize,
        f: &mut impl FnMut(ParallelStrategy),
    ) {
        let gbs = model.global_batch;
        let pp = cluster.pp();
        let min_lps = cluster.segments.iter().map(|s| s.layers_per_stage).min().unwrap_or(1);
        let max_lps = cluster.segments.iter().map(|s| s.layers_per_stage).max().unwrap_or(1);
        // Expert parallelism: only for MoE models; ep must divide both the
        // expert count and the data-parallel size (Megatron carves the EP
        // group out of DP).
        let eps: Vec<usize> = if model.is_moe() {
            self.config
                .ep_candidates
                .iter()
                .copied()
                .filter(|&e| model.num_experts % e == 0 && dp % e == 0)
                .collect()
        } else {
            vec![1]
        };
        for &mbs in &self.config.mbs_candidates {
            if gbs % (dp * mbs) != 0 {
                continue;
            }
            for &vpp in &self.config.vpp_candidates {
                if vpp > 1 && (pp == 1 || min_lps % vpp != 0 || max_lps % vpp != 0) {
                    continue;
                }
                for &sp in &self.config.seq_parallel_options {
                    if sp && tp == 1 {
                        continue;
                    }
                    for &dopt in &self.config.dist_opt_options {
                        for &off in &self.config.offload_options {
                            for rc in self.recompute_variants(max_lps) {
                              for &ep in &eps {
                                f(ParallelStrategy {
                                    cluster: cluster.clone(),
                                    tp,
                                    dp,
                                    micro_batch: mbs,
                                    global_batch: gbs,
                                    vpp,
                                    sequence_parallel: sp,
                                    use_distributed_optimizer: dopt,
                                    recompute: rc.0,
                                    recompute_method: rc.1,
                                    recompute_num_layers: rc.2,
                                    offload_optimizer: off,
                                    overlap_grad_reduce: self.config.overlap,
                                    overlap_param_gather: self.config.overlap,
                                    overlap_p2p: self.config.overlap,
                                    tp_comm_overlap: self.config.overlap,
                                    use_flash_attn: self.config.use_flash_attn,
                                    ep,
                                });
                              }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Recompute variants: none, selective, and full × {block, uniform} ×
    /// power-of-two layer counts (incl. the full per-stage layer count).
    fn recompute_variants(&self, layers_per_stage: usize) -> Vec<(Recompute, RecomputeMethod, usize)> {
        let mut v = Vec::new();
        if self.config.recompute_none {
            v.push((Recompute::None, RecomputeMethod::Uniform, 0));
        }
        if self.config.recompute_selective {
            v.push((Recompute::Selective, RecomputeMethod::Uniform, 0));
        }
        if self.config.recompute_full {
            let mut counts = Vec::new();
            let mut c = 1;
            while c < layers_per_stage {
                counts.push(c);
                c *= 2;
            }
            counts.push(layers_per_stage);
            for m in [RecomputeMethod::Block, RecomputeMethod::Uniform] {
                for &nl in &counts {
                    v.push((Recompute::Full, m, nl));
                }
            }
        }
        v
    }

    /// Mode-3 GPU-count sweep: powers of two up to `max_count` (Eq. 3).
    pub fn count_sweep(max_count: usize) -> Vec<usize> {
        let mut v = Vec::new();
        let mut c = 2;
        while c <= max_count {
            v.push(c);
            c *= 2;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuCatalog;
    use crate::model::ModelRegistry;

    fn setup() -> (ModelRegistry, GpuCatalog) {
        (ModelRegistry::builtin(), GpuCatalog::builtin())
    }

    #[test]
    fn all_generated_strategies_validate() {
        let (reg, cat) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies = space.homogeneous(m, &cat, 1, 64);
        assert!(!strategies.is_empty());
        for s in &strategies {
            s.validate(m).unwrap_or_else(|e| panic!("invalid strategy {}: {e}", s.summary()));
            assert_eq!(s.num_gpus(), 64);
        }
    }

    #[test]
    fn space_size_matches_paper_magnitude() {
        // Table 1 reports 23 348 strategies for Llama-2-7B@64 and 53 264 for
        // Llama-2-70B@64; our generator must land in the same order of
        // magnitude (10k–100k).
        let (reg, cat) = setup();
        let space = SearchSpace::new(SpaceConfig::default());
        let n7 = space.homogeneous(reg.get("llama2-7b").unwrap(), &cat, 1, 64).len();
        let n70 = space.homogeneous(reg.get("llama2-70b").unwrap(), &cat, 1, 64).len();
        assert!(n7 > 3_000 && n7 < 200_000, "llama2-7b@64 space = {n7}");
        assert!(n70 > n7, "70B space ({n70}) should exceed 7B space ({n7})");
    }

    #[test]
    fn space_shrinks_with_scale() {
        // Table 1: strategy count decreases as GPU count grows (fewer valid
        // dp/pp splittings of a fixed gbs).
        let (reg, cat) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let n64 = space.homogeneous(m, &cat, 1, 64).len();
        let n1024 = space.homogeneous(m, &cat, 1, 1024).len();
        assert!(n1024 < n64, "64 GPUs: {n64}, 1024 GPUs: {n1024}");
    }

    #[test]
    fn dp_only_config() {
        let (reg, cat) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let space = SearchSpace::new(SpaceConfig::dp_only());
        let strategies = space.homogeneous(m, &cat, 1, 64);
        assert!(!strategies.is_empty());
        for s in &strategies {
            assert_eq!(s.tp, 1);
            assert_eq!(s.pp(), 1);
            assert_eq!(s.dp, 64);
        }
    }

    #[test]
    fn tp_respects_heads_divisibility() {
        let (reg, cat) = setup();
        // A 12-head model cannot use tp=8.
        let mut m = reg.get("llama2-7b").unwrap().clone();
        m.heads = 12;
        m.kv_heads = 12;
        let space = SearchSpace::new(SpaceConfig::default());
        let tps = space.valid_tps(&m, &cat);
        assert_eq!(tps, vec![1, 2, 4]);
    }

    #[test]
    fn count_sweep_powers_of_two() {
        assert_eq!(SearchSpace::count_sweep(64), vec![2, 4, 8, 16, 32, 64]);
        assert_eq!(SearchSpace::count_sweep(100), vec![2, 4, 8, 16, 32, 64]);
    }

    #[test]
    fn no_duplicate_strategies() {
        let (reg, cat) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies = space.homogeneous(m, &cat, 1, 256);
        let mut keys: Vec<String> = strategies.iter().map(|s| s.summary()).collect();
        keys.sort();
        let before = keys.len();
        keys.dedup();
        assert_eq!(before, keys.len(), "duplicate strategies generated");
    }

    #[test]
    fn streamed_expansion_matches_collected_form() {
        // homogeneous() == homogeneous_pools() × expand_params_each(), in
        // order — the coordinator's streaming fan-out depends on this.
        let (reg, cat) = setup();
        let m = reg.get("llama2-7b").unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let collected = space.homogeneous(m, &cat, 1, 64);
        let mut streamed = Vec::new();
        for (cluster, tp, dp) in space.homogeneous_pools(m, &cat, 1, 64) {
            space.expand_params_each(m, &cluster, tp, dp, &mut |s| streamed.push(s));
        }
        assert_eq!(collected.len(), streamed.len());
        for (a, b) in collected.iter().zip(&streamed) {
            assert_eq!(a, b, "stream/collect order diverged");
        }
    }

    #[test]
    fn moe_space_includes_expert_parallel_variants() {
        let (reg, cat) = setup();
        let m = reg.get("mixtral-8x7b").unwrap();
        let space = SearchSpace::new(SpaceConfig::default());
        let strategies = space.homogeneous(m, &cat, 1, 64);
        assert!(!strategies.is_empty());
        let eps: std::collections::BTreeSet<usize> = strategies.iter().map(|s| s.ep).collect();
        assert!(eps.contains(&1) && eps.contains(&2) && eps.contains(&8), "eps seen: {eps:?}");
        for s in &strategies {
            s.validate(m).unwrap();
            assert_eq!(m.num_experts % s.ep, 0);
            assert_eq!(s.dp % s.ep, 0);
        }
        // Dense models never get ep > 1.
        let dense = space.homogeneous(reg.get("llama2-7b").unwrap(), &cat, 1, 64);
        assert!(dense.iter().all(|s| s.ep == 1));
    }
}
