//! Rule-based filter DSL (paper §3.3, Eq. 10–19).
//!
//! Users author rules as boolean expressions over `$`-prefixed strategy
//! fields; **a strategy matching any rule is dropped** (Eq. 10). The format
//! is `expression &&/|| expression ...` where `&&` binds tighter than `||`
//! and expressions evaluate left-to-right (Eq. 19).
//!
//! Grammar (recursive descent, see [`parse`]):
//!
//! ```text
//! or    := and ('||' and)*
//! and   := cmp ('&&' cmp)*
//! cmp   := sum (('=='|'!='|'>='|'<='|'>'|'<') sum)?
//! sum   := prod (('+'|'-') prod)*
//! prod  := unary (('*'|'/'|'%') unary)*
//! unary := '!' unary | atom
//! atom  := int | '$'ident | ident | 'None' | 'true' | 'false' | '(' or ')'
//! ```
//!
//! `=` is accepted as an alias for `==` (the paper writes single `=`).
//! Bare identifiers are symbols (e.g. `selective`); `$name` reads a strategy
//! field through the [`FieldSource`] trait.

mod eval;
mod lexer;
mod parser;

pub use eval::Val;
pub use parser::{parse, Expr};

use crate::Result;

/// Anything that can resolve `$field` references (implemented by
/// [`crate::strategy::ParallelStrategy`] plus test fixtures).
pub trait FieldSource {
    /// `None` means "field unknown" → rule evaluation error.
    fn field(&self, name: &str) -> Option<Val>;
}

/// A compiled rule: source + AST.
#[derive(Debug, Clone)]
pub struct Rule {
    pub source: String,
    expr: Expr,
}

impl Rule {
    pub fn compile(source: &str) -> Result<Rule> {
        Ok(Rule { source: source.to_string(), expr: parse(source)? })
    }

    /// True ⇒ the strategy violates this rule and must be filtered out.
    pub fn matches(&self, s: &dyn FieldSource) -> Result<bool> {
        Ok(eval::eval(&self.expr, s)?.truthy())
    }
}

/// An ordered collection of rules (a strategy survives iff no rule matches).
#[derive(Debug, Clone, Default)]
pub struct RuleSet {
    pub rules: Vec<Rule>,
}

impl RuleSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, source: &str) -> Result<()> {
        self.rules.push(Rule::compile(source)?);
        Ok(())
    }

    /// Parse a rule file: one rule per line, `#` comments, blank lines ok.
    pub fn from_text(text: &str) -> Result<RuleSet> {
        let mut rs = RuleSet::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            rs.add(line)?;
        }
        Ok(rs)
    }

    /// The paper's three example rules (§3.3) plus Megatron validity rules
    /// that any generated strategy must already satisfy (they act as a
    /// safety net over the generator).
    pub fn paper_defaults() -> RuleSet {
        let mut rs = RuleSet::new();
        // 1. Flash-attention rule: flash attention in use ⇒ selective
        //    recompute is redundant (flash already avoids storing scores).
        rs.add("$use_flash_attn != None && $recompute_granularity == selective").unwrap();
        // 2. Layer-recomputation rule.
        rs.add("$recompute_num_layers > $pipeline_model_parallel_size").unwrap();
        // 3. GPU-division rule.
        rs.add("$num_gpus % ($pipeline_model_parallel_size * $tensor_model_parallel_size) != 0")
            .unwrap();
        // Megatron validity: sequence parallel requires tensor parallel.
        rs.add("$sequence_parallel == true && $tensor_model_parallel_size == 1").unwrap();
        // Megatron validity: interleaving requires pp > 1.
        rs.add("$virtual_pipeline_parallel_size > 1 && $pipeline_model_parallel_size == 1")
            .unwrap();
        rs
    }

    /// True ⇒ filtered out (some rule matched). Propagates eval errors
    /// (unknown field / type mismatch) as [`crate::AstraError::Rule`].
    pub fn filters_out(&self, s: &dyn FieldSource) -> Result<bool> {
        for r in &self.rules {
            if r.matches(s)? {
                return Ok(true);
            }
        }
        Ok(false)
    }

    pub fn len(&self) -> usize {
        self.rules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use std::collections::BTreeMap;

    /// Simple map-backed field source for unit tests.
    #[derive(Default)]
    pub struct MapSource(pub BTreeMap<String, Val>);

    impl MapSource {
        pub fn with(mut self, k: &str, v: Val) -> Self {
            self.0.insert(k.to_string(), v);
            self
        }
    }

    impl FieldSource for MapSource {
        fn field(&self, name: &str) -> Option<Val> {
            self.0.get(name).cloned()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::MapSource;
    use super::*;

    #[test]
    fn paper_rule_flash_attention() {
        let rs = RuleSet::paper_defaults();
        let bad = MapSource::default()
            .with("use_flash_attn", Val::Bool(true))
            .with("recompute_granularity", Val::Sym("selective".into()))
            .with("recompute_num_layers", Val::Int(0))
            .with("pipeline_model_parallel_size", Val::Int(4))
            .with("tensor_model_parallel_size", Val::Int(2))
            .with("num_gpus", Val::Int(64))
            .with("sequence_parallel", Val::Bool(false))
            .with("virtual_pipeline_parallel_size", Val::Int(1));
        assert!(rs.filters_out(&bad).unwrap());
    }

    #[test]
    fn paper_rule_gpu_division() {
        let rs = RuleSet::paper_defaults();
        let mk = |gpus: i64, pp: i64, tp: i64| {
            MapSource::default()
                .with("use_flash_attn", Val::None)
                .with("recompute_granularity", Val::Sym("full".into()))
                .with("recompute_num_layers", Val::Int(1))
                .with("pipeline_model_parallel_size", Val::Int(pp))
                .with("tensor_model_parallel_size", Val::Int(tp))
                .with("num_gpus", Val::Int(gpus))
                .with("sequence_parallel", Val::Bool(false))
                .with("virtual_pipeline_parallel_size", Val::Int(1))
        };
        assert!(!rs.filters_out(&mk(64, 4, 2)).unwrap()); // 64 % 8 == 0 → keep
        assert!(rs.filters_out(&mk(60, 4, 2)).unwrap()); // 60 % 8 != 0 → drop
    }

    #[test]
    fn rule_file_parsing() {
        let rs = RuleSet::from_text("# comment\n\n$a > 3\n$b == x && $a < 2\n").unwrap();
        assert_eq!(rs.len(), 2);
        let s = MapSource::default().with("a", Val::Int(5)).with("b", Val::Sym("x".into()));
        assert!(rs.filters_out(&s).unwrap());
    }

    #[test]
    fn unknown_field_is_error() {
        let rs = RuleSet::from_text("$missing == 1").unwrap();
        let s = MapSource::default();
        assert!(rs.filters_out(&s).is_err());
    }
}
