//! Tokenizer for the rule DSL.

use crate::{AstraError, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Int(i64),
    /// `$field` reference.
    Var(String),
    /// Bare identifier (symbol like `selective`, or `true`/`false`/`None`).
    Ident(String),
    AndAnd,
    OrOr,
    Eq,
    Ne,
    Ge,
    Le,
    Gt,
    Lt,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Bang,
    LParen,
    RParen,
}

pub fn lex(src: &str) -> Result<Vec<Tok>> {
    let b = src.as_bytes();
    let mut i = 0;
    let mut out = Vec::new();
    let err = |i: usize, m: &str| AstraError::Rule(format!("{m} at column {i} in rule: {src}"));
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' => i += 1,
            b'(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            b'+' => {
                out.push(Tok::Plus);
                i += 1;
            }
            b'-' => {
                out.push(Tok::Minus);
                i += 1;
            }
            b'*' => {
                out.push(Tok::Star);
                i += 1;
            }
            b'/' => {
                out.push(Tok::Slash);
                i += 1;
            }
            b'%' => {
                out.push(Tok::Percent);
                i += 1;
            }
            b'&' => {
                if b.get(i + 1) == Some(&b'&') {
                    out.push(Tok::AndAnd);
                    i += 2;
                } else {
                    return Err(err(i, "single '&' (use '&&')"));
                }
            }
            b'|' => {
                if b.get(i + 1) == Some(&b'|') {
                    out.push(Tok::OrOr);
                    i += 2;
                } else {
                    return Err(err(i, "single '|' (use '||')"));
                }
            }
            b'=' => {
                // `==` canonical; bare `=` accepted (paper's Eq. 11 style).
                if b.get(i + 1) == Some(&b'=') {
                    i += 2;
                } else {
                    i += 1;
                }
                out.push(Tok::Eq);
            }
            b'!' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::Ne);
                    i += 2;
                } else {
                    out.push(Tok::Bang);
                    i += 1;
                }
            }
            b'>' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::Ge);
                    i += 2;
                } else {
                    out.push(Tok::Gt);
                    i += 1;
                }
            }
            b'<' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::Le);
                    i += 2;
                } else {
                    out.push(Tok::Lt);
                    i += 1;
                }
            }
            b'$' => {
                let start = i + 1;
                let mut j = start;
                while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                if j == start {
                    return Err(err(i, "'$' must be followed by a field name"));
                }
                out.push(Tok::Var(src[start..j].to_string()));
                i = j;
            }
            b'0'..=b'9' => {
                let start = i;
                let mut j = i;
                while j < b.len() && b[j].is_ascii_digit() {
                    j += 1;
                }
                let n: i64 = src[start..j]
                    .parse()
                    .map_err(|_| err(start, "integer literal out of range"))?;
                out.push(Tok::Int(n));
                i = j;
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                let mut j = i;
                while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                out.push(Tok::Ident(src[start..j].to_string()));
                i = j;
            }
            _ => return Err(err(i, "unexpected character")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lex_paper_rule() {
        let toks = lex("$use_flash_attn != None && $recompute_granularity = selective").unwrap();
        assert_eq!(
            toks,
            vec![
                Tok::Var("use_flash_attn".into()),
                Tok::Ne,
                Tok::Ident("None".into()),
                Tok::AndAnd,
                Tok::Var("recompute_granularity".into()),
                Tok::Eq,
                Tok::Ident("selective".into()),
            ]
        );
    }

    #[test]
    fn lex_arithmetic() {
        let toks = lex("$num_gpus % ($a * $b) != 0").unwrap();
        assert!(toks.contains(&Tok::Percent));
        assert!(toks.contains(&Tok::LParen));
    }

    #[test]
    fn lex_rejects() {
        assert!(lex("$").is_err());
        assert!(lex("a & b").is_err());
        assert!(lex("a | b").is_err());
        assert!(lex("a @ b").is_err());
        assert!(lex("999999999999999999999999").is_err());
    }

    #[test]
    fn single_equals_alias() {
        assert_eq!(lex("= ==").unwrap(), vec![Tok::Eq, Tok::Eq]);
    }
}
