//! Recursive-descent parser for the rule DSL (grammar in `mod.rs`).

use super::lexer::{lex, Tok};
use crate::{AstraError, Result};

/// Binary operators, in the AST.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Or,
    And,
    Eq,
    Ne,
    Gt,
    Ge,
    Lt,
    Le,
    Add,
    Sub,
    Mul,
    Div,
    Mod,
}

/// Expression AST.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Int(i64),
    /// `$field`
    Var(String),
    /// bare identifier (symbol); `true`/`false`/`None` are resolved at eval.
    Sym(String),
    Not(Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

pub fn parse(src: &str) -> Result<Expr> {
    let toks = lex(src)?;
    let mut p = P { toks: &toks, pos: 0, src };
    let e = p.or_expr()?;
    if p.pos != p.toks.len() {
        return Err(p.err("trailing tokens"));
    }
    Ok(e)
}

struct P<'a> {
    toks: &'a [Tok],
    pos: usize,
    src: &'a str,
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> AstraError {
        AstraError::Rule(format!("{msg} (token {} in rule: {})", self.pos, self.src))
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn eat(&mut self, t: &Tok) -> bool {
        if self.peek() == Some(t) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.and_expr()?;
        while self.eat(&Tok::OrOr) {
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.cmp_expr()?;
        while self.eat(&Tok::AndAnd) {
            let rhs = self.cmp_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr> {
        let lhs = self.sum_expr()?;
        let op = match self.peek() {
            Some(Tok::Eq) => BinOp::Eq,
            Some(Tok::Ne) => BinOp::Ne,
            Some(Tok::Gt) => BinOp::Gt,
            Some(Tok::Ge) => BinOp::Ge,
            Some(Tok::Lt) => BinOp::Lt,
            Some(Tok::Le) => BinOp::Le,
            _ => return Ok(lhs),
        };
        self.pos += 1;
        let rhs = self.sum_expr()?;
        Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
    }

    fn sum_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.prod_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Plus) => BinOp::Add,
                Some(Tok::Minus) => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.pos += 1;
            let rhs = self.prod_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn prod_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Star) => BinOp::Mul,
                Some(Tok::Slash) => BinOp::Div,
                Some(Tok::Percent) => BinOp::Mod,
                _ => return Ok(lhs),
            };
            self.pos += 1;
            let rhs = self.unary_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn unary_expr(&mut self) -> Result<Expr> {
        if self.eat(&Tok::Bang) {
            return Ok(Expr::Not(Box::new(self.unary_expr()?)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Expr> {
        match self.peek().cloned() {
            Some(Tok::Int(n)) => {
                self.pos += 1;
                Ok(Expr::Int(n))
            }
            Some(Tok::Var(name)) => {
                self.pos += 1;
                Ok(Expr::Var(name))
            }
            Some(Tok::Ident(name)) => {
                self.pos += 1;
                Ok(Expr::Sym(name))
            }
            Some(Tok::Minus) => {
                self.pos += 1;
                match self.atom()? {
                    Expr::Int(n) => Ok(Expr::Int(-n)),
                    e => Ok(Expr::Bin(BinOp::Sub, Box::new(Expr::Int(0)), Box::new(e))),
                }
            }
            Some(Tok::LParen) => {
                self.pos += 1;
                let e = self.or_expr()?;
                if !self.eat(&Tok::RParen) {
                    return Err(self.err("expected ')'"));
                }
                Ok(e)
            }
            _ => Err(self.err("expected expression")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn and_binds_tighter_than_or() {
        // a || b && c  ⇒  a || (b && c)
        let e = parse("$a || $b && $c").unwrap();
        match e {
            Expr::Bin(BinOp::Or, _, rhs) => match *rhs {
                Expr::Bin(BinOp::And, _, _) => {}
                other => panic!("rhs should be And, got {other:?}"),
            },
            other => panic!("top should be Or, got {other:?}"),
        }
    }

    #[test]
    fn left_assoc() {
        // a - b - c ⇒ (a-b)-c
        let e = parse("1 - 2 - 3").unwrap();
        match e {
            Expr::Bin(BinOp::Sub, lhs, _) => {
                assert!(matches!(*lhs, Expr::Bin(BinOp::Sub, _, _)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parens_override() {
        let e = parse("($a || $b) && $c").unwrap();
        assert!(matches!(e, Expr::Bin(BinOp::And, _, _)));
    }

    #[test]
    fn arithmetic_precedence() {
        // 2 + 3 * 4 ⇒ 2 + (3*4)
        let e = parse("2 + 3 * 4").unwrap();
        match e {
            Expr::Bin(BinOp::Add, _, rhs) => assert!(matches!(*rhs, Expr::Bin(BinOp::Mul, _, _))),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn negative_literal() {
        assert_eq!(parse("-5").unwrap(), Expr::Int(-5));
    }

    #[test]
    fn rejects_trailing_and_empty() {
        assert!(parse("").is_err());
        assert!(parse("$a $b").is_err());
        assert!(parse("($a").is_err());
        assert!(parse("$a &&").is_err());
    }
}
