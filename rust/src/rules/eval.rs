//! Evaluator for compiled rule expressions.

use super::parser::{BinOp, Expr};
use super::FieldSource;
use crate::{AstraError, Result};

/// Runtime value of the rule DSL.
#[derive(Debug, Clone, PartialEq)]
pub enum Val {
    Int(i64),
    Bool(bool),
    /// Bare-identifier symbol (`selective`, `block`, ...).
    Sym(String),
    /// Megatron's unset/None.
    None,
}

impl Val {
    /// Truthiness: the top level of a rule must be a boolean-ish value.
    pub fn truthy(&self) -> bool {
        match self {
            Val::Bool(b) => *b,
            Val::Int(n) => *n != 0,
            Val::Sym(_) => true,
            Val::None => false,
        }
    }
}

fn err(msg: String) -> AstraError {
    AstraError::Rule(msg)
}

pub fn eval(e: &Expr, src: &dyn FieldSource) -> Result<Val> {
    match e {
        Expr::Int(n) => Ok(Val::Int(*n)),
        Expr::Sym(s) => Ok(match s.as_str() {
            "true" => Val::Bool(true),
            "false" => Val::Bool(false),
            "None" | "none" | "null" => Val::None,
            _ => Val::Sym(s.clone()),
        }),
        Expr::Var(name) => src
            .field(name)
            .ok_or_else(|| err(format!("unknown strategy field '${name}'"))),
        Expr::Not(inner) => Ok(Val::Bool(!eval(inner, src)?.truthy())),
        Expr::Bin(op, l, r) => {
            match op {
                // Short-circuit logical ops.
                BinOp::And => {
                    let lv = eval(l, src)?;
                    if !lv.truthy() {
                        return Ok(Val::Bool(false));
                    }
                    Ok(Val::Bool(eval(r, src)?.truthy()))
                }
                BinOp::Or => {
                    let lv = eval(l, src)?;
                    if lv.truthy() {
                        return Ok(Val::Bool(true));
                    }
                    Ok(Val::Bool(eval(r, src)?.truthy()))
                }
                _ => {
                    let lv = eval(l, src)?;
                    let rv = eval(r, src)?;
                    apply(*op, lv, rv)
                }
            }
        }
    }
}

fn apply(op: BinOp, l: Val, r: Val) -> Result<Val> {
    use BinOp::*;
    match op {
        Eq => Ok(Val::Bool(val_eq(&l, &r))),
        Ne => Ok(Val::Bool(!val_eq(&l, &r))),
        Gt | Ge | Lt | Le => {
            let (a, b) = (as_int(&l, op)?, as_int(&r, op)?);
            Ok(Val::Bool(match op {
                Gt => a > b,
                Ge => a >= b,
                Lt => a < b,
                Le => a <= b,
                _ => unreachable!(),
            }))
        }
        Add | Sub | Mul | Div | Mod => {
            let (a, b) = (as_int(&l, op)?, as_int(&r, op)?);
            match op {
                Add => Ok(Val::Int(a.wrapping_add(b))),
                Sub => Ok(Val::Int(a.wrapping_sub(b))),
                Mul => Ok(Val::Int(a.wrapping_mul(b))),
                Div => {
                    if b == 0 {
                        Err(err("division by zero in rule".into()))
                    } else {
                        Ok(Val::Int(a / b))
                    }
                }
                Mod => {
                    if b == 0 {
                        Err(err("modulo by zero in rule".into()))
                    } else {
                        Ok(Val::Int(a % b))
                    }
                }
                _ => unreachable!(),
            }
        }
        And | Or => unreachable!("handled in eval"),
    }
}

/// Equality is polymorphic: Int==Int, Bool==Bool, Sym==Sym (case-insensitive),
/// None==None; a Bool compared with None uses "set-ness" semantics (the
/// paper's `$use_flash_attn != None` treats a set flag as non-None).
fn val_eq(l: &Val, r: &Val) -> bool {
    match (l, r) {
        (Val::Int(a), Val::Int(b)) => a == b,
        (Val::Bool(a), Val::Bool(b)) => a == b,
        (Val::Sym(a), Val::Sym(b)) => a.eq_ignore_ascii_case(b),
        (Val::None, Val::None) => true,
        (Val::Bool(b), Val::None) | (Val::None, Val::Bool(b)) => !b,
        (Val::Int(i), Val::Bool(b)) | (Val::Bool(b), Val::Int(i)) => (*i != 0) == *b,
        _ => false,
    }
}

fn as_int(v: &Val, op: BinOp) -> Result<i64> {
    match v {
        Val::Int(n) => Ok(*n),
        Val::Bool(b) => Ok(*b as i64),
        other => Err(err(format!("operator {op:?} needs integers, got {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::MapSource;
    use super::super::Rule;
    use super::*;

    fn src() -> MapSource {
        MapSource::default()
            .with("tp", Val::Int(4))
            .with("pp", Val::Int(8))
            .with("gpus", Val::Int(64))
            .with("flash", Val::Bool(true))
            .with("gran", Val::Sym("Selective".into()))
            .with("off", Val::None)
    }

    fn check(rule: &str, expect: bool) {
        let r = Rule::compile(rule).unwrap();
        assert_eq!(r.matches(&src()).unwrap(), expect, "rule: {rule}");
    }

    #[test]
    fn arithmetic_and_modulo() {
        check("$gpus % ($tp * $pp) != 0", false); // 64 % 32 == 0
        check("$gpus % ($tp * $pp * 2) != 0", false); // 64 % 64 == 0
        check("$gpus % 48 != 0", true);
        check("$gpus / $tp == 16", true);
        check("$gpus - $tp * $pp == 32", true); // precedence: 64 - 32
    }

    #[test]
    fn none_semantics() {
        check("$off == None", true);
        check("$flash != None", true);
        check("$off != None", false);
    }

    #[test]
    fn symbol_case_insensitive() {
        check("$gran == selective", true);
        check("$gran == SELECTIVE", true);
        check("$gran == full", false);
    }

    #[test]
    fn short_circuit_avoids_errors() {
        // Division by zero on the right of a false && must not evaluate.
        check("$tp > 100 && $gpus / 0 == 1", false);
        check("$tp == 4 || $gpus / 0 == 1", true);
    }

    #[test]
    fn division_by_zero_error() {
        let r = Rule::compile("$gpus % 0 == 0").unwrap();
        assert!(r.matches(&src()).is_err());
    }

    #[test]
    fn not_operator() {
        check("!($tp == 4)", false);
        check("!($tp == 5)", true);
    }

    #[test]
    fn comparison_type_error() {
        let r = Rule::compile("$gran > 3").unwrap();
        assert!(r.matches(&src()).is_err());
    }
}
