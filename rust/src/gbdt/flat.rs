//! Flattened SoA forest and the level-synchronous batch η-kernel.
//!
//! `FlatForest` is a read-only compilation of a [`Forest`](super::Forest):
//! every tree's node arrays are concatenated into four contiguous slabs
//! (`feat`, `thresh`, `qthresh`, `leaf`) with per-tree offsets, so batch
//! evaluation walks flat memory instead of chasing three `Vec`s per tree.
//!
//! ## SoA layout
//!
//! For tree `t` with depth `d`:
//!   - internal nodes live at `feat[node_off[t] .. node_off[t] + 2^d - 1]`
//!     (and the parallel `thresh` / `qthresh` slabs), in the same level
//!     order as `Tree::feat` / `Tree::thresh`;
//!   - leaves live at `leaf[leaf_off[t] .. leaf_off[t] + 2^d]`.
//!
//! ## Level-synchronous invariant
//!
//! The kernel is tree-outer, level-middle, row-inner: at each level every
//! row of the batch advances one step. Per row it tracks the *level-local*
//! index `li` (the scalar walk's `idx` minus the level base `2^L − 1`);
//! the transition `idx ← 2·idx + 1 + go_right` is exactly `li ← 2·li +
//! go_right` in level-local form, and after `d` levels `li` *is* the leaf
//! index. The branch decision `go_right = (x[f] ≥ t)` and the per-row
//! accumulation order (`acc += leaf` in tree order, then `base + lr·acc`,
//! all in f32) are identical to the scalar `Forest::predict`, so batch
//! results are bit-identical by construction.
//!
//! ## Quantized fast path and the exact-tie fallback
//!
//! Features and thresholds are mapped once through [`ordered_key`], a
//! monotone f32→u32 map (`-0.0` collapsed to `+0.0`, then a sign-flip of
//! the IEEE bits) under which `key(a) ≥ key(b) ⟺ a ≥ b` for all non-NaN
//! values. Branch decisions then compare u32 keys instead of floats. Two
//! guard rails keep the picks byte-identical to the float walk:
//!   - **exact-tie fallback**: whenever `key(x) == key(t)` the kernel
//!     re-decides on the original f32 compare `x ≥ t`, so a tie is routed
//!     exactly as the scalar walk routes it even if the key map were ever
//!     swapped for a lossy (bucketed) one;
//!   - **NaN fallback**: rows containing a NaN feature are flagged during
//!     quantization (the key map is only order-exact for non-NaN input)
//!     and re-scored with the exact scalar float walk, which sends NaN
//!     left at every node (`NaN ≥ t` is false) just like `Tree::predict`.

use super::Forest;

/// Monotone f32→u32 key: `key(a) >= key(b)` ⟺ `a >= b` for non-NaN a, b.
///
/// `-0.0` is collapsed to `+0.0` first (they compare equal as floats, so
/// they must share a key); negative floats have their bits inverted and
/// non-negative floats get the sign bit set, which maps the entire f32
/// line onto an order-isomorphic stretch of the u32 line. NaN keys are
/// meaningless — callers must route NaN input through the float fallback.
#[inline]
pub fn ordered_key(v: f32) -> u32 {
    let v = if v == 0.0 { 0.0 } else { v };
    let bits = v.to_bits();
    if bits & 0x8000_0000 != 0 {
        !bits
    } else {
        bits | 0x8000_0000
    }
}

/// Reusable buffers for [`FlatForest::predict_batch_with`]. Callers on the
/// hot path hold one of these and amortize every allocation across calls.
#[derive(Debug, Default, Clone)]
pub struct FlatScratch {
    /// Quantized feature keys, `rows × n_features`, row-major.
    qx: Vec<u32>,
    /// Per-row level-local node index within the current tree.
    li: Vec<u32>,
    /// Per-row f32 accumulator (tree-order partial sums).
    acc: Vec<f32>,
    /// Rows containing at least one NaN feature (scalar-walk fallback).
    nan_rows: Vec<u32>,
}

/// All trees of a [`Forest`] flattened into contiguous SoA slabs.
///
/// Built once (at `ScoringCore` / `CostModel` construction via
/// `EtaForests`) and read-only afterwards; see the module header for the
/// layout and the bit-identity argument.
#[derive(Debug, Clone)]
pub struct FlatForest {
    n_features: usize,
    base: f32,
    lr: f32,
    /// Per-tree depth (level count of internal nodes).
    depths: Vec<u32>,
    /// Per-tree start offset into `feat` / `thresh` / `qthresh`.
    node_off: Vec<u32>,
    /// Per-tree start offset into `leaf`.
    leaf_off: Vec<u32>,
    feat: Vec<u32>,
    thresh: Vec<f32>,
    /// `ordered_key` image of `thresh`, precomputed at build time.
    qthresh: Vec<u32>,
    leaf: Vec<f32>,
}

impl FlatForest {
    /// Flatten `forest` (assumed validated — `Forest::from_json` rejects
    /// malformed trees) into contiguous slabs.
    pub fn from_forest(forest: &Forest) -> FlatForest {
        let mut flat = FlatForest {
            n_features: forest.n_features,
            base: forest.base,
            lr: forest.lr,
            depths: Vec::with_capacity(forest.trees.len()),
            node_off: Vec::with_capacity(forest.trees.len()),
            leaf_off: Vec::with_capacity(forest.trees.len()),
            feat: Vec::new(),
            thresh: Vec::new(),
            qthresh: Vec::new(),
            leaf: Vec::new(),
        };
        for tree in &forest.trees {
            flat.depths.push(tree.depth as u32);
            flat.node_off.push(flat.feat.len() as u32);
            flat.leaf_off.push(flat.leaf.len() as u32);
            flat.feat.extend_from_slice(&tree.feat);
            flat.thresh.extend_from_slice(&tree.thresh);
            flat.qthresh.extend(tree.thresh.iter().map(|&t| ordered_key(t)));
            flat.leaf.extend_from_slice(&tree.leaf);
        }
        flat
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_trees(&self) -> usize {
        self.depths.len()
    }

    /// Batch prediction, quantized fast path. `xs` is row-major
    /// `rows × n_features`; predictions are appended to `out` (one per
    /// row). Convenience wrapper that allocates its own scratch — hot
    /// paths should call [`predict_batch_with`](Self::predict_batch_with).
    pub fn predict_batch_into(&self, xs: &[f32], out: &mut Vec<f32>) {
        let mut scratch = FlatScratch::default();
        self.predict_batch_with(xs, self.n_features.max(1), &mut scratch, out);
    }

    /// Batch prediction, quantized fast path, caller-owned scratch.
    /// `xs` is row-major with `stride` floats per row (`stride` may exceed
    /// `n_features` — scalar `Forest::predict` likewise tolerates longer
    /// rows). Appends one prediction per row to `out`; bit-identical to
    /// calling `Forest::predict` per row (see module header).
    pub fn predict_batch_with(
        &self,
        xs: &[f32],
        stride: usize,
        scratch: &mut FlatScratch,
        out: &mut Vec<f32>,
    ) {
        let nf = stride;
        assert!(
            nf >= self.n_features.max(1) && xs.len() % nf == 0,
            "xs length must be rows × stride, stride ≥ n_features"
        );
        let rows = xs.len() / nf;

        // Quantize every feature once; flag NaN-bearing rows for the
        // exact scalar fallback (the key map is order-exact only for
        // non-NaN input).
        scratch.qx.clear();
        scratch.qx.reserve(xs.len());
        scratch.nan_rows.clear();
        for (r, row) in xs.chunks_exact(nf).enumerate() {
            let mut has_nan = false;
            for &v in row {
                has_nan |= v.is_nan();
                scratch.qx.push(ordered_key(v));
            }
            if has_nan {
                scratch.nan_rows.push(r as u32);
            }
        }

        scratch.acc.clear();
        scratch.acc.resize(rows, 0.0);
        scratch.li.clear();
        scratch.li.resize(rows, 0);

        for t in 0..self.depths.len() {
            let depth = self.depths[t] as usize;
            let node0 = self.node_off[t] as usize;
            let leaf0 = self.leaf_off[t] as usize;
            scratch.li.iter_mut().for_each(|v| *v = 0);
            for level in 0..depth {
                // Internal nodes of this level occupy the contiguous
                // stretch [2^L − 1, 2^{L+1} − 1) of the tree's node slab.
                let level_base = node0 + (1usize << level) - 1;
                let width = 1usize << level;
                let feat = &self.feat[level_base..level_base + width];
                let qthresh = &self.qthresh[level_base..level_base + width];
                let thresh = &self.thresh[level_base..level_base + width];
                for r in 0..rows {
                    let li = scratch.li[r] as usize;
                    let f = feat[li] as usize;
                    let qt = qthresh[li];
                    let qv = scratch.qx[r * nf + f];
                    // Exact-tie fallback: on key equality, re-decide on
                    // the original float compare (see module header).
                    let go_right = if qv != qt {
                        (qv > qt) as u32
                    } else {
                        (xs[r * nf + f] >= thresh[li]) as u32
                    };
                    scratch.li[r] = 2 * scratch.li[r] + go_right;
                }
            }
            let leaves = &self.leaf[leaf0..leaf0 + (1usize << depth)];
            for r in 0..rows {
                scratch.acc[r] += leaves[scratch.li[r] as usize];
            }
        }

        let start = out.len();
        out.extend(scratch.acc.iter().map(|&a| self.base + self.lr * a));

        // NaN fallback: re-score flagged rows with the exact float walk.
        for &r in &scratch.nan_rows {
            let r = r as usize;
            out[start + r] = self.predict_row_float(&xs[r * nf..(r + 1) * nf]);
        }
    }

    /// Batch prediction with float compares at every node — the
    /// level-synchronous *reference* path (no quantization). Used by the
    /// differential tests to separate layout bugs from key-map bugs.
    pub fn predict_batch_float_into(&self, xs: &[f32], out: &mut Vec<f32>) {
        let nf = self.n_features.max(1);
        assert!(xs.len() % nf == 0, "xs length must be rows × n_features");
        let rows = xs.len() / nf;
        let mut li = vec![0u32; rows];
        let mut acc = vec![0.0f32; rows];
        for t in 0..self.depths.len() {
            let depth = self.depths[t] as usize;
            let node0 = self.node_off[t] as usize;
            let leaf0 = self.leaf_off[t] as usize;
            li.iter_mut().for_each(|v| *v = 0);
            for level in 0..depth {
                let level_base = node0 + (1usize << level) - 1;
                let width = 1usize << level;
                let feat = &self.feat[level_base..level_base + width];
                let thresh = &self.thresh[level_base..level_base + width];
                for r in 0..rows {
                    let i = li[r] as usize;
                    let f = feat[i] as usize;
                    let go_right = (xs[r * nf + f] >= thresh[i]) as u32;
                    li[r] = 2 * li[r] + go_right;
                }
            }
            let leaves = &self.leaf[leaf0..leaf0 + (1usize << depth)];
            for r in 0..rows {
                acc[r] += leaves[li[r] as usize];
            }
        }
        out.extend(acc.iter().map(|&a| self.base + self.lr * a));
    }

    /// Scalar float walk over the flat slabs for a single row — the exact
    /// arithmetic of `Tree::predict` / `Forest::predict`, used as the NaN
    /// fallback and as a self-contained reference.
    pub fn predict_row_float(&self, x: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for t in 0..self.depths.len() {
            let depth = self.depths[t] as usize;
            let node0 = self.node_off[t] as usize;
            let leaf0 = self.leaf_off[t] as usize;
            let mut li = 0usize;
            for level in 0..depth {
                let node = node0 + (1usize << level) - 1 + li;
                let f = self.feat[node] as usize;
                li = 2 * li + (x[f] >= self.thresh[node]) as usize;
            }
            acc += self.leaf[leaf0 + li];
        }
        self.base + self.lr * acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::Tree;

    fn demo_forest() -> Forest {
        let t1 = Tree {
            depth: 2,
            feat: vec![0, 1, 1],
            thresh: vec![0.5, 0.25, 0.75],
            leaf: vec![0.0, 1.0, 2.0, 3.0],
        };
        let t2 = Tree {
            depth: 1,
            feat: vec![1],
            thresh: vec![0.5],
            leaf: vec![-1.0, 4.0],
        };
        Forest { trees: vec![t1, t2], base: 0.25, lr: 0.5, n_features: 2 }
    }

    #[test]
    fn ordered_key_is_monotone_and_collapses_zero_signs() {
        let vals = [
            f32::NEG_INFINITY,
            -1e30,
            -1.0,
            -1e-30,
            -0.0,
            0.0,
            1e-30,
            1.0,
            1e30,
            f32::INFINITY,
        ];
        for i in 0..vals.len() {
            for j in 0..vals.len() {
                assert_eq!(
                    ordered_key(vals[i]) >= ordered_key(vals[j]),
                    vals[i] >= vals[j],
                    "key order mismatch for {} vs {}",
                    vals[i],
                    vals[j]
                );
            }
        }
        assert_eq!(ordered_key(-0.0), ordered_key(0.0));
    }

    #[test]
    fn flat_matches_scalar_on_demo_forest() {
        let forest = demo_forest();
        let flat = FlatForest::from_forest(&forest);
        let rows: Vec<[f32; 2]> = vec![
            [0.0, 0.0],
            [0.5, 0.25], // exact ties on both splits of t1, below t2 split
            [0.5, 0.5],  // tie routes right everywhere
            [1.0, 1.0],
            [0.49, 0.75],
            [-0.0, 0.0],
        ];
        let xs: Vec<f32> = rows.iter().flatten().copied().collect();
        let mut out = Vec::new();
        flat.predict_batch_into(&xs, &mut out);
        let mut out_f = Vec::new();
        flat.predict_batch_float_into(&xs, &mut out_f);
        for (r, row) in rows.iter().enumerate() {
            let want = forest.predict(row);
            assert_eq!(out[r].to_bits(), want.to_bits(), "quantized row {r}");
            assert_eq!(out_f[r].to_bits(), want.to_bits(), "float-ref row {r}");
            assert_eq!(flat.predict_row_float(row).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn nan_rows_fall_back_to_the_scalar_walk() {
        let forest = demo_forest();
        let flat = FlatForest::from_forest(&forest);
        // NaN compares false against every threshold → always left,
        // exactly like Tree::predict.
        let xs = [f32::NAN, f32::NAN, 0.9, f32::NAN, 1.0, 1.0];
        let mut out = Vec::new();
        flat.predict_batch_into(&xs, &mut out);
        for r in 0..3 {
            let want = forest.predict(&xs[r * 2..r * 2 + 2]);
            assert!(!want.is_nan());
            assert_eq!(out[r].to_bits(), want.to_bits(), "row {r}");
        }
    }

    #[test]
    fn scratch_reuse_is_clean_across_calls() {
        let forest = demo_forest();
        let flat = FlatForest::from_forest(&forest);
        let mut scratch = FlatScratch::default();
        let mut out = Vec::new();
        let a = [f32::NAN, 0.1, 0.6, 0.6];
        flat.predict_batch_with(&a, 2, &mut scratch, &mut out);
        out.clear();
        let b = [0.5, 0.25, 0.9, 0.9, 0.1, 0.1];
        flat.predict_batch_with(&b, 2, &mut scratch, &mut out);
        for r in 0..3 {
            let want = forest.predict(&b[r * 2..r * 2 + 2]);
            assert_eq!(out[r].to_bits(), want.to_bits(), "row {r}");
        }
    }
}
