//! Gradient-boosted-tree forest inference (the paper's "XGBoost model").
//!
//! Trees are *complete* binary trees of fixed depth in level order: internal
//! nodes `0..2^d−1` carry `(feature, threshold)`, leaves `0..2^d` carry
//! values. Descent is branch-free (`idx ← 2·idx + 1 + (x[f] ≥ t)`), which is
//! exactly the layout the Layer-1 Pallas kernel (`kernels/forest.py`)
//! vectorizes; this module is its scalar mirror, used by the `native`
//! scoring engine and by the HLO↔native parity tests.
//!
//! Forests are trained at build time by `python/compile/gbdt_train.py` and
//! interchanged via `artifacts/forest.json`.

use crate::json::Value;
use crate::{AstraError, Result};
use std::sync::OnceLock;

pub mod flat;
pub use flat::{FlatForest, FlatScratch};

/// One complete regression tree.
#[derive(Debug, Clone)]
pub struct Tree {
    pub depth: usize,
    /// Feature index per internal node (len `2^depth − 1`).
    pub feat: Vec<u32>,
    /// Split threshold per internal node (len `2^depth − 1`).
    pub thresh: Vec<f32>,
    /// Leaf values (len `2^depth`).
    pub leaf: Vec<f32>,
}

impl Tree {
    /// Branch-free descent; `x` must have at least `max(feat)+1` entries.
    pub fn predict(&self, x: &[f32]) -> f32 {
        let mut idx = 0usize;
        for _ in 0..self.depth {
            let f = self.feat[idx] as usize;
            let go_right = (x[f] >= self.thresh[idx]) as usize;
            idx = 2 * idx + 1 + go_right;
        }
        self.leaf[idx - (self.feat.len())] // internal count = 2^d − 1
    }

    fn validate(&self) -> Result<()> {
        let internal = (1usize << self.depth) - 1;
        let leaves = 1usize << self.depth;
        if self.feat.len() != internal || self.thresh.len() != internal || self.leaf.len() != leaves
        {
            return Err(AstraError::Json(format!(
                "tree shape mismatch: depth {} wants {internal} internal / {leaves} leaves, got {}/{}/{}",
                self.depth,
                self.feat.len(),
                self.thresh.len(),
                self.leaf.len()
            )));
        }
        Ok(())
    }
}

/// A boosted ensemble: `ŷ = base + lr · Σ_t tree_t(x)`.
#[derive(Debug, Clone)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub base: f32,
    pub lr: f32,
    pub n_features: usize,
}

impl Forest {
    pub fn predict(&self, x: &[f32]) -> f32 {
        debug_assert!(x.len() >= self.n_features);
        let mut acc = 0.0f32;
        for t in &self.trees {
            acc += t.predict(x);
        }
        self.base + self.lr * acc
    }

    /// Batched prediction (row-major `xs`, `n_features` stride).
    pub fn predict_batch(&self, xs: &[f32], out: &mut Vec<f32>) {
        out.clear();
        for row in xs.chunks_exact(self.n_features) {
            out.push(self.predict(row));
        }
    }

    /// A forest that always predicts `v` (tests and fallbacks).
    pub fn constant(v: f32, n_features: usize) -> Forest {
        Forest { trees: Vec::new(), base: v, lr: 1.0, n_features }
    }

    /// Parse the `artifacts/forest.json` interchange format:
    ///
    /// ```json
    /// { "n_features": 6, "base": 0.5, "lr": 0.1,
    ///   "trees": [ {"depth":4, "feat":[...], "thresh":[...], "leaf":[...]} ] }
    /// ```
    pub fn from_json(v: &Value) -> Result<Forest> {
        let n_features = v
            .get("n_features")
            .and_then(Value::as_usize)
            .ok_or_else(|| AstraError::Json("forest: missing n_features".into()))?;
        let base = v.req_f64("base")? as f32;
        let lr = v.req_f64("lr")? as f32;
        let mut trees = Vec::new();
        for tv in v.req_arr("trees")? {
            let depth = tv
                .get("depth")
                .and_then(Value::as_usize)
                .ok_or_else(|| AstraError::Json("tree: missing depth".into()))?;
            let tree = Tree {
                depth,
                feat: tv.req_f64_arr("feat")?.iter().map(|&f| f as u32).collect(),
                thresh: tv.req_f64_arr("thresh")?.iter().map(|&f| f as f32).collect(),
                leaf: tv.req_f64_arr("leaf")?.iter().map(|&f| f as f32).collect(),
            };
            tree.validate()?;
            if let Some(&f) = tree.feat.iter().max() {
                if f as usize >= n_features {
                    return Err(AstraError::Json(format!(
                        "tree references feature {f} but n_features={n_features}"
                    )));
                }
            }
            trees.push(tree);
        }
        Ok(Forest { trees, base, lr, n_features })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Forest> {
        Self::from_json(&crate::json::from_file(path)?)
    }
}

/// The pair of forests used by the cost model (η_comp, η_comm), plus the
/// clamp to `[1e-4, 1.0]` — the paper treats η as lying in (0, 1], and the
/// `1e-4` floor keeps the `t = θ/(φ·η)` division away from raw-prediction
/// zeros/negatives while `1.0` caps efficiency at the hardware peak.
/// (`f64::clamp` propagates NaN, but a NaN *prediction* cannot occur for
/// finite leaves: a NaN feature routes left at every split — `NaN ≥ t` is
/// false — and still lands on a finite leaf.)
///
/// Each forest also carries a lazily-built [`FlatForest`] mirror for the
/// batched η path (`eta_comp_batch` / `eta_comm_batch`); it is derived
/// state, built on first use and invisible to persistence digests.
#[derive(Debug, Clone)]
pub struct EtaForests {
    pub comp: Forest,
    pub comm: Forest,
    flat_comp: OnceLock<FlatForest>,
    flat_comm: OnceLock<FlatForest>,
}

impl EtaForests {
    pub fn new(comp: Forest, comm: Forest) -> EtaForests {
        EtaForests { comp, comm, flat_comp: OnceLock::new(), flat_comm: OnceLock::new() }
    }

    /// Load `artifacts/forest.json` holding both ensembles.
    pub fn from_file(path: &std::path::Path) -> Result<EtaForests> {
        let v = crate::json::from_file(path)?;
        let comp = Forest::from_json(
            v.get("comp").ok_or_else(|| AstraError::Json("missing 'comp' forest".into()))?,
        )?;
        let comm = Forest::from_json(
            v.get("comm").ok_or_else(|| AstraError::Json("missing 'comm' forest".into()))?,
        )?;
        Ok(EtaForests::new(comp, comm))
    }

    /// The flattened mirror of `comp`, built on first use.
    pub fn flat_comp(&self) -> &FlatForest {
        self.flat_comp.get_or_init(|| FlatForest::from_forest(&self.comp))
    }

    /// The flattened mirror of `comm`, built on first use.
    pub fn flat_comm(&self) -> &FlatForest {
        self.flat_comm.get_or_init(|| FlatForest::from_forest(&self.comm))
    }

    pub fn eta_comp(&self, features: &[f32]) -> f64 {
        (self.comp.predict(features) as f64).clamp(1e-4, 1.0)
    }

    pub fn eta_comm(&self, features: &[f32]) -> f64 {
        (self.comm.predict(features) as f64).clamp(1e-4, 1.0)
    }

    /// Batched η_comp over row-major `xs` (`stride` floats per row, e.g.
    /// `hw::COMP_FEATURES`) via the flat kernel; appends one clamped η per
    /// row to `out`. Bit-identical to calling [`eta_comp`](Self::eta_comp)
    /// per row (the flat kernel is bit-identical to `Forest::predict`, and
    /// the clamp is applied identically).
    pub fn eta_comp_batch(
        &self,
        xs: &[f32],
        stride: usize,
        scratch: &mut FlatScratch,
        pred: &mut Vec<f32>,
        out: &mut Vec<f64>,
    ) {
        pred.clear();
        self.flat_comp().predict_batch_with(xs, stride, scratch, pred);
        out.extend(pred.iter().map(|&p| (p as f64).clamp(1e-4, 1.0)));
    }

    /// Batched η_comm; see [`eta_comp_batch`](Self::eta_comp_batch).
    pub fn eta_comm_batch(
        &self,
        xs: &[f32],
        stride: usize,
        scratch: &mut FlatScratch,
        pred: &mut Vec<f32>,
        out: &mut Vec<f64>,
    ) {
        pred.clear();
        self.flat_comm().predict_batch_with(xs, stride, scratch, pred);
        out.extend(pred.iter().map(|&p| (p as f64).clamp(1e-4, 1.0)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    /// depth-2 tree splitting on x0 then x1, leaves = [0,1,2,3].
    fn demo_tree() -> Tree {
        Tree {
            depth: 2,
            feat: vec![0, 1, 1],
            thresh: vec![0.5, 0.25, 0.75],
            leaf: vec![0.0, 1.0, 2.0, 3.0],
        }
    }

    #[test]
    fn descent_reaches_all_leaves() {
        let t = demo_tree();
        assert_eq!(t.predict(&[0.0, 0.0]), 0.0); // L,L
        assert_eq!(t.predict(&[0.0, 0.3]), 1.0); // L,R
        assert_eq!(t.predict(&[0.9, 0.0]), 2.0); // R,L
        assert_eq!(t.predict(&[0.9, 0.9]), 3.0); // R,R
    }

    #[test]
    fn forest_combines_base_lr() {
        let f = Forest { trees: vec![demo_tree(), demo_tree()], base: 10.0, lr: 0.5, n_features: 2 };
        // two identical trees → base + 0.5 * 2 * leaf
        assert_eq!(f.predict(&[0.9, 0.9]), 10.0 + 0.5 * 6.0);
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{
            "n_features": 2, "base": 0.1, "lr": 1.0,
            "trees": [{"depth": 1, "feat": [0], "thresh": [0.5], "leaf": [2.0, 4.0]}]
        }"#;
        let f = Forest::from_json(&parse(src).unwrap()).unwrap();
        assert!((f.predict(&[0.0, 0.0]) - 2.1).abs() < 1e-6);
        assert!((f.predict(&[1.0, 0.0]) - 4.1).abs() < 1e-6);
    }

    #[test]
    fn shape_validation() {
        let bad = r#"{
            "n_features": 2, "base": 0, "lr": 1,
            "trees": [{"depth": 2, "feat": [0], "thresh": [0.5], "leaf": [1, 2]}]
        }"#;
        assert!(Forest::from_json(&parse(bad).unwrap()).is_err());
        let oob = r#"{
            "n_features": 1, "base": 0, "lr": 1,
            "trees": [{"depth": 1, "feat": [3], "thresh": [0.5], "leaf": [1, 2]}]
        }"#;
        assert!(Forest::from_json(&parse(oob).unwrap()).is_err());
    }

    #[test]
    fn batch_matches_scalar() {
        let f = Forest { trees: vec![demo_tree()], base: 0.0, lr: 1.0, n_features: 2 };
        let xs = [0.0f32, 0.0, 0.0, 0.3, 0.9, 0.0, 0.9, 0.9];
        let mut out = Vec::new();
        f.predict_batch(&xs, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn eta_clamped() {
        let ef = EtaForests::new(Forest::constant(7.0, 1), Forest::constant(-3.0, 1));
        assert_eq!(ef.eta_comp(&[0.0]), 1.0);
        assert_eq!(ef.eta_comm(&[0.0]), 1e-4);
    }

    #[test]
    fn eta_clamp_boundaries_are_exact() {
        // Predictions landing exactly on the clamp rails pass through
        // untouched; values just past the rails are pinned to them.
        let lo = EtaForests::new(Forest::constant(1e-4, 1), Forest::constant(1.0, 1));
        assert_eq!(lo.eta_comp(&[0.0]), (1e-4f32 as f64).clamp(1e-4, 1.0));
        assert_eq!(lo.eta_comm(&[0.0]), 1.0);
        let under = EtaForests::new(Forest::constant(9.9e-5, 1), Forest::constant(0.0, 1));
        assert_eq!(under.eta_comp(&[0.0]), 1e-4);
        assert_eq!(under.eta_comm(&[0.0]), 1e-4); // raw 0.0 floors to 1e-4
        let over = EtaForests::new(Forest::constant(1.0 + f32::EPSILON, 1), Forest::constant(-0.5, 1));
        assert_eq!(over.eta_comp(&[0.0]), 1.0);
        assert_eq!(over.eta_comm(&[0.0]), 1e-4); // negatives floor to 1e-4
    }

    #[test]
    fn eta_nan_input_routes_left_and_stays_finite() {
        // A NaN *feature* never yields a NaN η: every split compares
        // `NaN ≥ t` = false, so descent goes left and lands on a finite
        // leaf, which then clamps normally.
        let tree = Tree { depth: 1, feat: vec![0], thresh: vec![0.5], leaf: vec![0.25, 0.75] };
        let forest = Forest { trees: vec![tree], base: 0.0, lr: 1.0, n_features: 1 };
        let ef = EtaForests::new(forest.clone(), forest);
        let eta = ef.eta_comp(&[f32::NAN]);
        assert_eq!(eta, 0.25f32 as f64); // the left leaf, inside the clamp band
        assert_eq!(ef.eta_comm(&[f32::NAN]), 0.25f32 as f64);
    }

    #[test]
    fn flat_mirror_is_lazily_built_and_matches() {
        let f = Forest { trees: vec![demo_tree()], base: 0.5, lr: 2.0, n_features: 2 };
        let ef = EtaForests::new(f.clone(), f.clone());
        let xs = [0.0f32, 0.0, 0.9, 0.9, 0.5, 0.25];
        let mut out = Vec::new();
        ef.flat_comp().predict_batch_into(&xs, &mut out);
        for (r, row) in xs.chunks_exact(2).enumerate() {
            assert_eq!(out[r].to_bits(), f.predict(row).to_bits());
        }
        // Batched η applies the same clamp as the scalar accessor.
        let mut scratch = FlatScratch::default();
        let mut pred = Vec::new();
        let mut etas = Vec::new();
        ef.eta_comp_batch(&xs, 2, &mut scratch, &mut pred, &mut etas);
        for (r, row) in xs.chunks_exact(2).enumerate() {
            assert_eq!(etas[r].to_bits(), ef.eta_comp(row).to_bits());
        }
    }
}
