//! Search-path perf instrument: the fig7 hetero-cost workload, cold
//! (fresh `SharedCostMemo`) vs memo-warm (same engine, repeated) vs
//! warm-restore (fresh engine fed from a spilled `astra::persist`
//! snapshot — the restarted-service story), plus the strictly serial
//! workers=1/wave=1 oracle execution of the same plan for context. Writes
//! the machine-readable `BENCH_search.json` perf-trajectory artifact —
//! strategies/sec, memo hit-rate, wall seconds per leg (see the
//! `astra::cost` module docs for how to read it).
//!
//! Env knobs:
//! * `ASTRA_BENCH_FAST=1`       — smaller caps for smoke/CI runs;
//! * `ASTRA_BENCH_OUT=<path>`   — where to write `BENCH_search.json`
//!                                (default: `BENCH_search.json` in cwd);
//! * `ASTRA_BENCH_MIN_HIT_RATE=<0..1>` — exit nonzero if the *warm* memo
//!   hit-rate drops below this floor (the `BENCH=1 ./ci.sh` gate);
//! * `ASTRA_BENCH_MIN_RESTORE_HIT_RATE=<0..1>` — same floor for the
//!   *warm_restore* leg (restore must actually skip the cold pass);
//! * `ASTRA_BENCH_MAX_TRACE_OVERHEAD=<ratio>` — exit nonzero if the
//!   *telemetry_overhead* leg (cold search with the flight recorder
//!   streaming vs the untraced cold leg) exceeds this fractional slowdown
//!   (e.g. `0.05` = 5%);
//! * `ASTRA_BENCH_MAX_AUDIT_OVERHEAD=<ratio>` — same cap for the
//!   *audit_overhead* leg (cold search with the decision audit assembled
//!   vs the unaudited cold leg): the explain plane must stay a bookkeeping
//!   pass over the replay, never extra search work;
//! * `ASTRA_BENCH_MIN_REPRICE_SPEEDUP=<ratio>` — exit nonzero if the
//!   *frontier_reprice* leg (re-billing a held frontier report under a
//!   rate-only price-book change vs a cold frontier re-search under the
//!   same new book) speeds up by less than this factor — the money axis
//!   of the frontier cache story (`BENCH=1 ./ci.sh` pins 100×);
//! * `ASTRA_BENCH_MIN_HLO_PARITY=<0..1>` — run the HLO-parity smoke on the
//!   fig5 workload (llama2-7b, homogeneous a800): the HLO engine's
//!   streamed per-pool path must pick the same strategy as the native
//!   engine (parity 1.0 = identical best pick; fractional = top-3
//!   overlap). Skipped with a notice when the PJRT artifacts are absent,
//!   like `crosscheck_hw.rs`;
//! * `ASTRA_BENCH_MIN_ETA_SPEEDUP=<ratio>` — exit nonzero if the η-kernel
//!   speedup falls below this floor. The gated figure is the *cold_forest*
//!   leg (cold search with forest η, `batch_eta` on vs off) when trained
//!   artifacts exist, else the *eta_kernel* micro-leg (flat SoA batch
//!   kernel vs the scalar per-row `Forest::predict` walk on a synthetic
//!   deterministic forest). Both legs assert bit-identical predictions
//!   before timing anything (`BENCH=1 ./ci.sh` pins 3×).

use astra::bench_util::section;
use astra::coordinator::{AstraEngine, EngineConfig, ScoringEngine, SearchReport, SearchRequest};
use astra::gbdt::{EtaForests, FlatForest, FlatScratch, Forest, Tree};
use astra::gpu::GpuCatalog;
use astra::json::Value;
use astra::model::ModelRegistry;
use astra::prng::Rng;
use std::time::Instant;

fn engine() -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, ..Default::default() },
    )
}

/// The strictly serial oracle: one worker, wave pinned to 1/1 — the same
/// plan the other engines execute, with all parallelism off.
fn oracle() -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            use_forests: false,
            workers: 1,
            sweep_wave: 1,
            sweep_wave_max: 1,
            ..Default::default()
        },
    )
}

fn hit_rate(r: &SearchReport) -> f64 {
    let total = r.memo_hits + r.memo_misses;
    if total == 0 {
        0.0
    } else {
        r.memo_hits as f64 / total as f64
    }
}

fn leg_json(r: &SearchReport, secs: f64) -> Value {
    Value::obj()
        .set("wall_secs", secs)
        .set("generated", r.generated)
        .set("scored", r.scored)
        .set("pruned_pools", r.pruned_pools)
        .set("strategies_per_sec", r.generated as f64 / secs.max(1e-12))
        .set("memo_hits", r.memo_hits)
        .set("memo_misses", r.memo_misses)
        .set("memo_hit_rate", hit_rate(r))
}

/// HLO-vs-native pick parity on the fig5 workload: 1.0 when the best
/// strategies are identical, else the fraction of the native top-3 the HLO
/// ranking reproduces.
fn hlo_parity(native: &SearchReport, hlo: &SearchReport) -> f64 {
    match (native.best(), hlo.best()) {
        (Some(n), Some(h)) if n.strategy == h.strategy => 1.0,
        _ => {
            let top_n: Vec<_> = native.top.iter().take(3).map(|s| &s.strategy).collect();
            let top_h: Vec<_> = hlo.top.iter().take(3).map(|s| &s.strategy).collect();
            if top_n.is_empty() {
                return 0.0;
            }
            let shared = top_n.iter().filter(|s| top_h.contains(*s)).count();
            shared as f64 / top_n.len() as f64
        }
    }
}

/// Deterministic synthetic η-forest: the micro-leg must run (and stay
/// comparable across machines) without trained artifacts on disk.
fn synthetic_eta_forest(seed: u64, n_features: usize) -> Forest {
    let mut rng = Rng::new(seed);
    let trees: Vec<Tree> = (0..64)
        .map(|_| {
            let depth = 1 + rng.below(6) as usize;
            let internal = (1usize << depth) - 1;
            Tree {
                depth,
                feat: (0..internal).map(|_| rng.below(n_features as u64) as u32).collect(),
                thresh: (0..internal).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect(),
                leaf: (0..1usize << depth).map(|_| rng.range_f64(0.05, 1.2) as f32).collect(),
            }
        })
        .collect();
    Forest { trees, base: 0.3, lr: 0.05, n_features }
}

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let registry = ModelRegistry::builtin();
    let model = registry.get("llama2-7b").unwrap().clone();
    let cap = if fast { 12 } else { 48 };
    let caps = vec![("a800", cap), ("h100", cap)];
    let req = SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap();

    section(&format!(
        "perf_search — fig7 hetero-cost workload, llama2-7b on ≤{cap}×a800 + ≤{cap}×h100"
    ));

    // Cold: fresh engine, empty memo. This is the first-request latency a
    // service tenant sees for a new model scope.
    let eng = engine();
    let t = Instant::now();
    let cold_rep = eng.search(&req).unwrap();
    let cold_secs = t.elapsed().as_secs_f64();
    println!(
        "cold : {cold_secs:.3}s  {} generated, {} scored, memo {}/{} ({:.1}% hit)",
        cold_rep.generated,
        cold_rep.scored,
        cold_rep.memo_hits,
        cold_rep.memo_misses,
        100.0 * hit_rate(&cold_rep)
    );

    // Warm: same engine — every stage/sync profile is already resident.
    // Best of two runs so a scheduler hiccup cannot poison the headline.
    let mut warm_secs = f64::INFINITY;
    let mut warm_rep = None;
    for _ in 0..2 {
        let t = Instant::now();
        let r = eng.search(&req).unwrap();
        let secs = t.elapsed().as_secs_f64();
        if secs < warm_secs {
            warm_secs = secs;
            warm_rep = Some(r);
        }
    }
    let warm_rep = warm_rep.unwrap();
    println!(
        "warm : {warm_secs:.3}s  memo {}/{} ({:.1}% hit)",
        warm_rep.memo_hits,
        warm_rep.memo_misses,
        100.0 * hit_rate(&warm_rep)
    );

    // Restore: spill the warm engine's scopes, load them into a *fresh*
    // engine — simulating a restarted process — and search. The restored
    // pass must hit like the warm pass (it has the same profiles resident)
    // while having paid only a file parse instead of the cold compute.
    let warm_file =
        std::env::temp_dir().join(format!("astra_warm_bench_{}.jsonl", std::process::id()));
    let spill = eng.core().save_warm(&warm_file).unwrap();
    let eng_restored = engine();
    let restore = eng_restored.core().load_warm(&warm_file).unwrap();
    let t = Instant::now();
    let restore_rep = eng_restored.search(&req).unwrap();
    let restore_secs = t.elapsed().as_secs_f64();
    let _ = std::fs::remove_file(&warm_file);
    println!(
        "rest : {restore_secs:.3}s  {} scope(s) restored ({} rejected), memo {}/{} ({:.1}% hit)",
        restore.scopes_restored,
        restore.scopes_rejected,
        restore_rep.memo_hits,
        restore_rep.memo_misses,
        100.0 * hit_rate(&restore_rep)
    );

    // Oracle: the same plan, strictly serial (workers=1, wave=1/1) on a
    // fresh engine — the differential harness's oracle, and the trajectory
    // context for how much the parallel executor buys.
    let t = Instant::now();
    let oracle_rep = oracle().search(&req).unwrap();
    let oracle_secs = t.elapsed().as_secs_f64();
    println!("serial: {oracle_secs:.3}s  (workers=1/wave=1 oracle execution)");

    // Telemetry: the same cold workload with the flight recorder streaming
    // span events — the opt-in cost of turning tracing on. (Tracing *off*
    // costs one relaxed atomic load per guard; this leg bounds the *on*
    // path against the untraced cold leg above.)
    let trace_file =
        std::env::temp_dir().join(format!("astra_trace_bench_{}.jsonl", std::process::id()));
    astra::telemetry::trace::enable(&trace_file).unwrap();
    let t = Instant::now();
    let traced_rep = engine().search(&req).unwrap();
    let traced_secs = t.elapsed().as_secs_f64();
    astra::telemetry::trace::disable();
    let trace_events =
        std::fs::read_to_string(&trace_file).map(|s| s.lines().count()).unwrap_or(0);
    let _ = std::fs::remove_file(&trace_file);
    let trace_overhead = traced_secs / cold_secs.max(1e-12) - 1.0;
    println!(
        "trace: {traced_secs:.3}s with the recorder on ({trace_events} span(s), {:+.1}% vs cold)",
        100.0 * trace_overhead
    );

    // Audit: the same cold workload with the decision audit assembled —
    // the opt-in cost of the explain plane. The audit rides the serial
    // replay the executor runs anyway, so this leg prices pure bookkeeping
    // (struct pushes per pool), not extra search work.
    let t = Instant::now();
    let audited_rep = engine().search_audited(&req).unwrap();
    let audited_secs = t.elapsed().as_secs_f64();
    let audit = audited_rep.audit.as_ref().expect("audited search carries an audit");
    let audit_overhead = audited_secs / cold_secs.max(1e-12) - 1.0;
    println!(
        "audit: {audited_secs:.3}s with the audit on ({} pool(s) recorded, {:+.1}% vs cold)",
        audit.pool_count(),
        100.0 * audit_overhead
    );
    // Auditing is a view switch, not a different search: the canonical
    // report bytes must be identical with it on or off.
    assert_eq!(
        astra::json::to_string_pretty(&astra::report::report_json(
            &cold_rep,
            &GpuCatalog::builtin()
        )),
        astra::json::to_string_pretty(&astra::report::report_json(
            &audited_rep,
            &GpuCatalog::builtin()
        )),
        "the audit changed the canonical report"
    );

    let speedup = cold_secs / warm_secs.max(1e-12);
    println!(
        "memo-warm speedup: {speedup:.2}×  ({cold_secs:.3}s → {warm_secs:.3}s); \
         parallel executor vs serial oracle (cold): {:.2}×",
        oracle_secs / cold_secs.max(1e-12)
    );

    // Sanity: warmth and parallelism must not change what is selected.
    let best = |r: &SearchReport| {
        r.best().map(|s| (s.cost.tokens_per_s.to_bits(), s.money_usd.to_bits()))
    };
    assert_eq!(best(&cold_rep), best(&warm_rep), "memo warmth changed the selection");
    assert_eq!(best(&cold_rep), best(&oracle_rep), "executor diverged from the serial oracle");
    assert_eq!(best(&cold_rep), best(&restore_rep), "restored memo changed the selection");
    assert_eq!(best(&cold_rep), best(&traced_rep), "flight recorder changed the selection");

    // Frontier reprice: cold frontier search under the builtin book, then a
    // rate-only book change — re-billing the held report must match a cold
    // re-search under the new book byte-for-byte while skipping the engine
    // entirely. This is the service's cached-frontier path; the leg prices
    // how much the skip buys.
    let catalog = GpuCatalog::builtin();
    let fr_req = SearchRequest::frontier(&caps, model.clone()).unwrap();
    let t = Instant::now();
    let fr_cold_a = engine().search(&fr_req).unwrap();
    let fr_cold_a_secs = t.elapsed().as_secs_f64();
    let mut book_b = astra::pricing::PriceBook::builtin();
    for e in astra::pricing::PriceBook::builtin().entries() {
        book_b.upsert(astra::pricing::PriceEntry {
            gpu: e.gpu.clone(),
            on_demand_per_hour: e.on_demand_per_hour * 1.7,
            spot_per_hour: e.spot_per_hour * 1.7,
        });
    }
    book_b.use_spot = true;
    let money_b = astra::pareto::MoneyModel { book: book_b, ..Default::default() };
    // Best of three: the reprice is microseconds against seconds, so a
    // single scheduler hiccup would otherwise dominate the ratio.
    let mut reprice_secs = f64::INFINITY;
    let mut repriced = None;
    for _ in 0..3 {
        let t = Instant::now();
        let r = fr_cold_a.reprice(&model, &catalog, &money_b).expect("frontier reprice");
        let secs = t.elapsed().as_secs_f64();
        if secs < reprice_secs {
            reprice_secs = secs;
            repriced = Some(r);
        }
    }
    let repriced = repriced.unwrap();
    let eng_b = AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, money: money_b.clone(), ..Default::default() },
    );
    let t = Instant::now();
    let fr_cold_b = eng_b.search(&fr_req).unwrap();
    let fr_cold_b_secs = t.elapsed().as_secs_f64();
    assert_eq!(
        astra::json::to_string_pretty(&astra::report::report_json(&repriced, &catalog)),
        astra::json::to_string_pretty(&astra::report::report_json(&fr_cold_b, &catalog)),
        "reprice diverged from a cold frontier search under the new book"
    );
    let reprice_speedup = fr_cold_b_secs / reprice_secs.max(1e-12);
    println!(
        "reprice: {:.1}µs vs {fr_cold_b_secs:.3}s cold re-search ({reprice_speedup:.0}× — \
         {} frontier point(s), byte-identical result)",
        reprice_secs * 1e6,
        repriced.pool.len()
    );

    // --- η-kernel micro-leg: scalar per-row walk vs the flat SoA batch ---
    // The scalar side mirrors the pre-batching production path exactly:
    // one `Forest::predict` call per memo miss. Predictions must match
    // bit-for-bit before any timing is reported. Best of 3 per side so a
    // scheduler hiccup cannot poison the ratio.
    let nf = astra::hw::COMP_FEATURES;
    let eta_forest = synthetic_eta_forest(0x0e7a_5eed, nf);
    let flat = FlatForest::from_forest(&eta_forest);
    let rows = if fast { 20_000 } else { 200_000 };
    let mut rng = Rng::new(0x0e7a_40b5);
    let xs: Vec<f32> = (0..rows * nf).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect();

    let mut scalar_out: Vec<f32> = Vec::with_capacity(rows);
    let mut scalar_kernel_secs = f64::INFINITY;
    for _ in 0..3 {
        scalar_out.clear();
        let t = Instant::now();
        for row in xs.chunks_exact(nf) {
            scalar_out.push(eta_forest.predict(row));
        }
        scalar_kernel_secs = scalar_kernel_secs.min(t.elapsed().as_secs_f64());
    }

    let mut scratch = FlatScratch::default();
    let mut flat_out: Vec<f32> = Vec::new();
    let mut flat_kernel_secs = f64::INFINITY;
    for _ in 0..3 {
        flat_out.clear(); // predict_batch_with appends
        let t = Instant::now();
        flat.predict_batch_with(&xs, nf, &mut scratch, &mut flat_out);
        flat_kernel_secs = flat_kernel_secs.min(t.elapsed().as_secs_f64());
    }
    assert_eq!(scalar_out.len(), flat_out.len());
    for (i, (a, b)) in scalar_out.iter().zip(flat_out.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "eta_kernel row {i}: flat kernel diverged");
    }
    let eta_kernel_speedup = scalar_kernel_secs / flat_kernel_secs.max(1e-12);
    println!(
        "eta-kernel: {rows} rows × {} trees — scalar {:.1}ms vs flat {:.1}ms \
         ({eta_kernel_speedup:.2}×, bit-identical)",
        eta_forest.trees.len(),
        scalar_kernel_secs * 1e3,
        flat_kernel_secs * 1e3
    );

    // --- Forest-η cold legs (need trained artifacts on disk) ---
    // The end-to-end figure the micro-leg approximates: a cold search with
    // forest η, batched kernel on vs off, byte-identical reports.
    let mut forest_legs: Option<(SearchReport, f64, SearchReport, f64)> = None;
    if EtaForests::from_file(&astra::runtime::artifacts_dir().join("forest.json")).is_ok() {
        let mk = |batch_eta: bool| {
            AstraEngine::new(
                GpuCatalog::builtin(),
                EngineConfig { use_forests: true, batch_eta, ..Default::default() },
            )
        };
        let t = Instant::now();
        let rep_scalar = mk(false).search(&req).unwrap();
        let forest_scalar_secs = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let rep_batch = mk(true).search(&req).unwrap();
        let forest_batch_secs = t.elapsed().as_secs_f64();
        assert_eq!(
            astra::json::to_string_pretty(&astra::report::report_json(
                &rep_batch,
                &GpuCatalog::builtin()
            )),
            astra::json::to_string_pretty(&astra::report::report_json(
                &rep_scalar,
                &GpuCatalog::builtin()
            )),
            "batched η changed the forest-η cold report"
        );
        println!(
            "cold-forest: scalar η {forest_scalar_secs:.3}s vs batched η {forest_batch_secs:.3}s \
             ({:.2}×, byte-identical report)",
            forest_scalar_secs / forest_batch_secs.max(1e-12)
        );
        forest_legs = Some((rep_scalar, forest_scalar_secs, rep_batch, forest_batch_secs));
    } else {
        println!("cold-forest: SKIP — no trained artifacts/forest.json (micro-leg gates instead)");
    }

    let mut out = Value::obj()
        .set(
            "workload",
            Value::obj()
                .set("mode", "hetero-cost")
                .set("model", model.name.as_str())
                .set("caps", {
                    let mut o = Value::obj();
                    for &(name, c) in &caps {
                        o = o.set(name, c);
                    }
                    o
                })
                .set("max_money", "inf")
                .set("fast", fast)
                .set("workers", eng.core().config.workers),
        )
        .set("cold", leg_json(&cold_rep, cold_secs))
        .set("warm", leg_json(&warm_rep, warm_secs))
        .set(
            "warm_restore",
            leg_json(&restore_rep, restore_secs)
                .set("scopes_restored", restore.scopes_restored)
                .set("scopes_rejected", restore.scopes_rejected)
                .set("snapshot_bytes", spill.bytes),
        )
        .set("oracle_serial", leg_json(&oracle_rep, oracle_secs))
        .set(
            "telemetry_overhead",
            leg_json(&traced_rep, traced_secs)
                .set("trace_events", trace_events)
                .set("overhead_vs_cold", trace_overhead),
        )
        .set(
            "audit_overhead",
            leg_json(&audited_rep, audited_secs)
                .set("audited_pools", audit.pool_count())
                .set("audit_admitted", audit.admitted())
                .set("audit_pruned_budget", audit.pruned_budget())
                .set("audit_pruned_dominated", audit.pruned_dominated())
                .set("overhead_vs_cold", audit_overhead),
        )
        .set("speedup_warm_vs_cold", speedup)
        .set("speedup_restore_vs_cold", cold_secs / restore_secs.max(1e-12))
        .set(
            "frontier_reprice",
            leg_json(&fr_cold_b, fr_cold_b_secs)
                .set("cold_first_book_secs", fr_cold_a_secs)
                .set("reprice_secs", reprice_secs)
                .set("frontier_points", repriced.pool.len())
                .set("speedup_reprice_vs_cold", reprice_speedup),
        )
        .set(
            "eta_kernel",
            Value::obj()
                .set("rows", rows)
                .set("trees", eta_forest.trees.len())
                .set("features", nf)
                .set("scalar_secs", scalar_kernel_secs)
                .set("flat_secs", flat_kernel_secs)
                .set("speedup_flat_vs_scalar", eta_kernel_speedup),
        );
    if let Some((rep_scalar, scalar_secs, rep_batch, batch_secs)) = &forest_legs {
        out = out
            .set("cold_forest_scalar_eta", leg_json(rep_scalar, *scalar_secs))
            .set(
                "cold_forest_batched_eta",
                leg_json(rep_batch, *batch_secs)
                    .set("speedup_batched_vs_scalar", scalar_secs / batch_secs.max(1e-12)),
            );
    }

    // --- HLO parity smoke (gated): fig5 workload through both engines ---
    let mut parity_result: Option<(f64, bool)> = None;
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_HLO_PARITY") {
        let floor: f64 = floor.parse().expect("ASTRA_BENCH_MIN_HLO_PARITY must be a number");
        if !astra::runtime::artifacts_present() {
            println!("hlo-parity: SKIP — PJRT artifacts missing (run `make artifacts`)");
        } else {
            // Identical config on both sides (default space + forest η —
            // the HLO scorer was trained against forest η, so this is the
            // apples-to-apples comparison); ASTRA_BENCH_FAST narrows the
            // space like the other legs narrow their caps.
            let parity_cfg = || {
                let mut cfg = EngineConfig::default();
                if fast {
                    cfg.space = astra::strategy::SpaceConfig {
                        mbs_candidates: vec![1, 2, 4],
                        vpp_candidates: vec![1],
                        offload_options: vec![false],
                        ..astra::strategy::SpaceConfig::default()
                    };
                }
                cfg
            };
            let hlo_eng = AstraEngine::new(
                GpuCatalog::builtin(),
                EngineConfig { engine: ScoringEngine::Hlo, ..parity_cfg() },
            );
            if !hlo_eng.hlo_active() {
                println!("hlo-parity: SKIP — PJRT runtime failed to load");
            } else {
                let native_eng = AstraEngine::new(GpuCatalog::builtin(), parity_cfg());
                let fig5 =
                    SearchRequest::homogeneous("a800", 32, model.clone()).expect("fig5 request");
                let native_rep = native_eng.search(&fig5).unwrap();
                let hlo_rep = hlo_eng.search(&fig5).unwrap();
                assert!(
                    hlo_rep.memo_hits + hlo_rep.memo_misses == 0,
                    "HLO engine must score through PJRT, not the memo"
                );
                let parity = hlo_parity(&native_rep, &hlo_rep);
                let ok = parity >= floor;
                println!(
                    "hlo-parity: {parity:.2} (floor {floor:.2}) — native best {} vs hlo best {}",
                    native_rep.best().map(|s| s.strategy.summary()).unwrap_or_default(),
                    hlo_rep.best().map(|s| s.strategy.summary()).unwrap_or_default()
                );
                out = out.set(
                    "hlo_parity",
                    Value::obj()
                        .set("parity", parity)
                        .set("floor", floor)
                        .set("generated", hlo_rep.generated)
                        .set("scored", hlo_rep.scored),
                );
                parity_result = Some((parity, ok));
            }
        }
    }

    let path = std::env::var("ASTRA_BENCH_OUT").unwrap_or_else(|_| "BENCH_search.json".into());
    match std::fs::write(&path, astra::json::to_string_pretty(&out) + "\n") {
        Ok(()) => println!("(json: {path})"),
        Err(e) => eprintln!("perf_search: could not write {path}: {e}"),
    }

    // CI floor: the warm hit-rate is the memo's health signal — it decays
    // if keys start carrying incidental state or the registry mis-scopes.
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_HIT_RATE") {
        let floor: f64 = floor.parse().expect("ASTRA_BENCH_MIN_HIT_RATE must be a number");
        let got = hit_rate(&warm_rep);
        if got < floor {
            eprintln!(
                "perf_search: FAIL — warm memo hit-rate {got:.3} below pinned floor {floor:.3}"
            );
            std::process::exit(1);
        }
        println!("warm memo hit-rate {got:.3} ≥ floor {floor:.3} — ok");
    }

    // Same floor for the restore leg: a restored snapshot that misses is a
    // persistence regression (format drift, digest over-rejection, rows
    // dropped), even if the warm leg stays healthy.
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_RESTORE_HIT_RATE") {
        let floor: f64 =
            floor.parse().expect("ASTRA_BENCH_MIN_RESTORE_HIT_RATE must be a number");
        let got = hit_rate(&restore_rep);
        if got < floor || restore.scopes_restored == 0 {
            eprintln!(
                "perf_search: FAIL — restored hit-rate {got:.3} (floor {floor:.3}), \
                 {} scope(s) restored",
                restore.scopes_restored
            );
            std::process::exit(1);
        }
        println!("restored memo hit-rate {got:.3} ≥ floor {floor:.3} — ok");
    }

    // The whole point of serving frontiers from cache is skipping the
    // engine: if repricing stops being orders of magnitude cheaper than a
    // cold re-search, the cache path has regressed into a slow path.
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_REPRICE_SPEEDUP") {
        let floor: f64 =
            floor.parse().expect("ASTRA_BENCH_MIN_REPRICE_SPEEDUP must be a number");
        if reprice_speedup < floor {
            eprintln!(
                "perf_search: FAIL — frontier reprice speedup {reprice_speedup:.1}× below \
                 pinned floor {floor:.1}×"
            );
            std::process::exit(1);
        }
        println!("frontier reprice speedup {reprice_speedup:.1}× ≥ floor {floor:.1}× — ok");
    }

    // Tracing is opt-in, but the opt-in must stay cheap: gate the on-vs-off
    // slowdown when a cap is pinned.
    if let Ok(cap) = std::env::var("ASTRA_BENCH_MAX_TRACE_OVERHEAD") {
        let cap: f64 = cap.parse().expect("ASTRA_BENCH_MAX_TRACE_OVERHEAD must be a number");
        if trace_overhead > cap {
            eprintln!(
                "perf_search: FAIL — tracing overhead {trace_overhead:.3} above cap {cap:.3}"
            );
            std::process::exit(1);
        }
        println!("tracing overhead {trace_overhead:.3} ≤ cap {cap:.3} — ok");
    }

    // Same shape for the explain plane: an audit that costs real search
    // time means it stopped being replay bookkeeping.
    if let Ok(cap) = std::env::var("ASTRA_BENCH_MAX_AUDIT_OVERHEAD") {
        let cap: f64 = cap.parse().expect("ASTRA_BENCH_MAX_AUDIT_OVERHEAD must be a number");
        if audit_overhead > cap {
            eprintln!(
                "perf_search: FAIL — audit overhead {audit_overhead:.3} above cap {cap:.3}"
            );
            std::process::exit(1);
        }
        println!("audit overhead {audit_overhead:.3} ≤ cap {cap:.3} — ok");
    }

    // η-kernel floor: the SoA batch kernel is the whole point of the flat
    // forest layout — gate the end-to-end forest cold leg when trained
    // artifacts exist, else the micro-kernel ratio.
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_ETA_SPEEDUP") {
        let floor: f64 = floor.parse().expect("ASTRA_BENCH_MIN_ETA_SPEEDUP must be a number");
        let (which, got) = match &forest_legs {
            Some((_, scalar_secs, _, batch_secs)) => {
                ("cold_forest", scalar_secs / batch_secs.max(1e-12))
            }
            None => ("eta_kernel", eta_kernel_speedup),
        };
        if got < floor {
            eprintln!(
                "perf_search: FAIL — {which} η speedup {got:.2}× below pinned floor {floor:.2}×"
            );
            std::process::exit(1);
        }
        println!("{which} η speedup {got:.2}× ≥ floor {floor:.2}× — ok");
    }

    // HLO parity gate (only when the smoke actually ran — skips pass).
    if let Some((parity, ok)) = parity_result {
        if !ok {
            eprintln!("perf_search: FAIL — HLO pick parity {parity:.2} below floor");
            std::process::exit(1);
        }
        println!("hlo pick parity {parity:.2} — ok");
    }
}
