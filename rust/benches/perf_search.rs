//! Search-path perf instrument: the fig7 hetero-cost workload, cold
//! (fresh `SharedCostMemo`) vs memo-warm (same engine, repeated) vs
//! warm-restore (fresh engine fed from a spilled `astra::persist`
//! snapshot — the restarted-service story), plus the pre-refactor
//! non-streaming reference for context. Writes the machine-readable
//! `BENCH_search.json` perf-trajectory artifact — strategies/sec, memo
//! hit-rate, wall seconds per leg (see the `astra::cost` module docs for
//! how to read it).
//!
//! Env knobs:
//! * `ASTRA_BENCH_FAST=1`       — smaller caps for smoke/CI runs;
//! * `ASTRA_BENCH_OUT=<path>`   — where to write `BENCH_search.json`
//!                                (default: `BENCH_search.json` in cwd);
//! * `ASTRA_BENCH_MIN_HIT_RATE=<0..1>` — exit nonzero if the *warm* memo
//!   hit-rate drops below this floor (the `BENCH=1 ./ci.sh` gate);
//! * `ASTRA_BENCH_MIN_RESTORE_HIT_RATE=<0..1>` — same floor for the
//!   *warm_restore* leg (restore must actually skip the cold pass).

use astra::bench_util::section;
use astra::coordinator::{AstraEngine, EngineConfig, SearchReport, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::json::Value;
use astra::model::ModelRegistry;
use std::time::Instant;

fn engine(streaming: bool) -> AstraEngine {
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig { use_forests: false, streaming, ..Default::default() },
    )
}

fn hit_rate(r: &SearchReport) -> f64 {
    let total = r.memo_hits + r.memo_misses;
    if total == 0 {
        0.0
    } else {
        r.memo_hits as f64 / total as f64
    }
}

fn leg_json(r: &SearchReport, secs: f64) -> Value {
    Value::obj()
        .set("wall_secs", secs)
        .set("generated", r.generated)
        .set("scored", r.scored)
        .set("pruned_pools", r.pruned_pools)
        .set("strategies_per_sec", r.generated as f64 / secs.max(1e-12))
        .set("memo_hits", r.memo_hits)
        .set("memo_misses", r.memo_misses)
        .set("memo_hit_rate", hit_rate(r))
}

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let registry = ModelRegistry::builtin();
    let model = registry.get("llama2-7b").unwrap().clone();
    let cap = if fast { 12 } else { 48 };
    let caps = vec![("a800", cap), ("h100", cap)];
    let req = SearchRequest::hetero_cost(&caps, f64::INFINITY, model.clone()).unwrap();

    section(&format!(
        "perf_search — fig7 hetero-cost workload, llama2-7b on ≤{cap}×a800 + ≤{cap}×h100"
    ));

    // Cold: fresh engine, empty memo. This is the first-request latency a
    // service tenant sees for a new model scope.
    let eng = engine(true);
    let t = Instant::now();
    let cold_rep = eng.search(&req).unwrap();
    let cold_secs = t.elapsed().as_secs_f64();
    println!(
        "cold : {cold_secs:.3}s  {} generated, {} scored, memo {}/{} ({:.1}% hit)",
        cold_rep.generated,
        cold_rep.scored,
        cold_rep.memo_hits,
        cold_rep.memo_misses,
        100.0 * hit_rate(&cold_rep)
    );

    // Warm: same engine — every stage/sync profile is already resident.
    // Best of two runs so a scheduler hiccup cannot poison the headline.
    let mut warm_secs = f64::INFINITY;
    let mut warm_rep = None;
    for _ in 0..2 {
        let t = Instant::now();
        let r = eng.search(&req).unwrap();
        let secs = t.elapsed().as_secs_f64();
        if secs < warm_secs {
            warm_secs = secs;
            warm_rep = Some(r);
        }
    }
    let warm_rep = warm_rep.unwrap();
    println!(
        "warm : {warm_secs:.3}s  memo {}/{} ({:.1}% hit)",
        warm_rep.memo_hits,
        warm_rep.memo_misses,
        100.0 * hit_rate(&warm_rep)
    );

    // Restore: spill the warm engine's scopes, load them into a *fresh*
    // engine — simulating a restarted process — and search. The restored
    // pass must hit like the warm pass (it has the same profiles resident)
    // while having paid only a file parse instead of the cold compute.
    let warm_file =
        std::env::temp_dir().join(format!("astra_warm_bench_{}.jsonl", std::process::id()));
    let spill = eng.core().save_warm(&warm_file).unwrap();
    let eng_restored = engine(true);
    let restore = eng_restored.core().load_warm(&warm_file).unwrap();
    let t = Instant::now();
    let restore_rep = eng_restored.search(&req).unwrap();
    let restore_secs = t.elapsed().as_secs_f64();
    let _ = std::fs::remove_file(&warm_file);
    println!(
        "rest : {restore_secs:.3}s  {} scope(s) restored ({} rejected), memo {}/{} ({:.1}% hit)",
        restore.scopes_restored,
        restore.scopes_rejected,
        restore_rep.memo_hits,
        restore_rep.memo_misses,
        100.0 * hit_rate(&restore_rep)
    );

    // Reference: the pre-refactor collect-then-filter pipeline with
    // per-chunk memos (context for the trajectory, not a gated number).
    let t = Instant::now();
    let ref_rep = engine(false).search(&req).unwrap();
    let ref_secs = t.elapsed().as_secs_f64();
    println!("ref  : {ref_secs:.3}s  (non-streaming reference path)");

    let speedup = cold_secs / warm_secs.max(1e-12);
    println!(
        "memo-warm speedup: {speedup:.2}×  ({cold_secs:.3}s → {warm_secs:.3}s); \
         streaming vs reference cold: {:.2}×",
        ref_secs / cold_secs.max(1e-12)
    );

    // Sanity: warmth must not change what is selected.
    let best = |r: &SearchReport| {
        r.best().map(|s| (s.cost.tokens_per_s.to_bits(), s.money_usd.to_bits()))
    };
    assert_eq!(best(&cold_rep), best(&warm_rep), "memo warmth changed the selection");
    assert_eq!(best(&cold_rep), best(&ref_rep), "streaming diverged from the reference");
    assert_eq!(best(&cold_rep), best(&restore_rep), "restored memo changed the selection");

    let out = Value::obj()
        .set(
            "workload",
            Value::obj()
                .set("mode", "hetero-cost")
                .set("model", model.name.as_str())
                .set("caps", {
                    let mut o = Value::obj();
                    for &(name, c) in &caps {
                        o = o.set(name, c);
                    }
                    o
                })
                .set("max_money", "inf")
                .set("fast", fast)
                .set("workers", eng.core().config.workers),
        )
        .set("cold", leg_json(&cold_rep, cold_secs))
        .set("warm", leg_json(&warm_rep, warm_secs))
        .set(
            "warm_restore",
            leg_json(&restore_rep, restore_secs)
                .set("scopes_restored", restore.scopes_restored)
                .set("scopes_rejected", restore.scopes_rejected)
                .set("snapshot_bytes", spill.bytes),
        )
        .set("reference_nonstreaming", leg_json(&ref_rep, ref_secs))
        .set("speedup_warm_vs_cold", speedup)
        .set("speedup_restore_vs_cold", cold_secs / restore_secs.max(1e-12));

    let path = std::env::var("ASTRA_BENCH_OUT").unwrap_or_else(|_| "BENCH_search.json".into());
    match std::fs::write(&path, astra::json::to_string_pretty(&out) + "\n") {
        Ok(()) => println!("(json: {path})"),
        Err(e) => eprintln!("perf_search: could not write {path}: {e}"),
    }

    // CI floor: the warm hit-rate is the memo's health signal — it decays
    // if keys start carrying incidental state or the registry mis-scopes.
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_HIT_RATE") {
        let floor: f64 = floor.parse().expect("ASTRA_BENCH_MIN_HIT_RATE must be a number");
        let got = hit_rate(&warm_rep);
        if got < floor {
            eprintln!(
                "perf_search: FAIL — warm memo hit-rate {got:.3} below pinned floor {floor:.3}"
            );
            std::process::exit(1);
        }
        println!("warm memo hit-rate {got:.3} ≥ floor {floor:.3} — ok");
    }

    // Same floor for the restore leg: a restored snapshot that misses is a
    // persistence regression (format drift, digest over-rejection, rows
    // dropped), even if the warm leg stays healthy.
    if let Ok(floor) = std::env::var("ASTRA_BENCH_MIN_RESTORE_HIT_RATE") {
        let floor: f64 =
            floor.parse().expect("ASTRA_BENCH_MIN_RESTORE_HIT_RATE must be a number");
        let got = hit_rate(&restore_rep);
        if got < floor || restore.scopes_restored == 0 {
            eprintln!(
                "perf_search: FAIL — restored hit-rate {got:.3} (floor {floor:.3}), \
                 {} scope(s) restored",
                restore.scopes_restored
            );
            std::process::exit(1);
        }
        println!("restored memo hit-rate {got:.3} ≥ floor {floor:.3} — ok");
    }
}
