//! Figure 8 (appendix B.2) — all parallelism methods vs data-parallel only.
//!
//! Paper shape: DP-only degrades sharply as the system scales (gradient
//! all-reduce dominates) or fails outright for big models; the hybrid space
//! keeps scaling.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::strategy::SpaceConfig;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let full = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let dp_only = AstraEngine::new(
        catalog.clone(),
        EngineConfig { space: SpaceConfig::dp_only(), ..Default::default() },
    );

    let counts: &[usize] = if fast { &[64, 256] } else { &[64, 128, 256, 1024, 4096] };
    // Paper uses the models small enough for pure DP.
    let models = ["llama2-7b", "llama2-13b", "llama3-8b"];

    let mut t = Table::new(&["Model", "#GPU", "DP-only tokens/s", "hybrid tokens/s", "hybrid gain"]);
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        for &count in counts {
            let req = SearchRequest::homogeneous("a800", count, model.clone()).expect("request");
            let hybrid = full
                .search(&req)
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s))
                .unwrap_or(0.0);
            let dp = dp_only
                .search(&req)
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s));
            t.row(&[
                name.to_string(),
                count.to_string(),
                dp.map(|v| format!("{v:.0}")).unwrap_or_else(|| "OOM/invalid".into()),
                format!("{hybrid:.0}"),
                dp.map(|v| format!("{:.2}×", hybrid / v)).unwrap_or_else(|| "∞".into()),
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Fig. 8 — DP-only vs all-parallelism (paper: hybrid gain grows with scale)",
        Some(std::path::Path::new("bench_out/fig8.csv")),
    );
}
