//! Table 1 — search-space size and per-phase time cost.
//!
//! For every paper model × GPU count: #Strategies (the generated space
//! |S|), Search Time (generation + rule/memory filtering), Simulation Time
//! (cost scoring) and E2E. The paper's shape to reproduce: the space
//! shrinks as GPUs grow, search ≪ simulation, E2E in seconds-to-a-minute.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let engine = AstraEngine::new(catalog.clone(), EngineConfig::default());

    let counts: &[usize] = if fast { &[64, 256] } else { &[64, 256, 1024, 4096] };
    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-70b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
    };

    let mut t = Table::new(&[
        "Model",
        "#GPU",
        "#Strategies",
        "Search Time(/s)",
        "Simulation Time(/s)",
        "E2E Time(/s)",
    ]);
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        for &count in counts {
            let rep = engine
                .search(&SearchRequest::homogeneous("a800", count, model.clone()).expect("request"))
                .unwrap();
            t.row(&[
                name.to_string(),
                count.to_string(),
                rep.generated.to_string(),
                format!("{:.4}", rep.search_secs),
                format!("{:.4}", rep.simulate_secs),
                format!("{:.4}", rep.e2e_secs()),
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Table 1 — search space and time cost (paper: search <1s, simulation dominates)",
        Some(std::path::Path::new("bench_out/table1.csv")),
    );

    println!("\nshape notes:");
    println!("  paper magnitudes: 4.7k–53k strategies; search 0.02–0.1s; simulation 17–69s");
    println!("  (our cost evaluation is a CPU analytic model, so simulation is faster in absolute terms)");
}
