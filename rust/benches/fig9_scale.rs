//! Figure 9 (appendix B.3) — system-scale impact on training efficiency.
//!
//! Per-GPU throughput of the searched optimum as the cluster grows with the
//! model fixed. Paper shape: per-GPU throughput decays with scale, and the
//! decay is steeper for the bigger models (communication + bubble overheads
//! overtake compute).

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let engine = AstraEngine::new(catalog.clone(), EngineConfig::default());

    let counts: &[usize] = if fast { &[64, 256, 1024] } else { &[64, 128, 256, 512, 1024, 4096] };
    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-70b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
    };

    let mut t = Table::new(&["Model", "#GPU", "tokens/s", "tokens/s/GPU", "scaling eff %"]);
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        let mut base_per_gpu: Option<f64> = None;
        for &count in counts {
            let Some(best) = engine
                .search(&SearchRequest::homogeneous("a800", count, model.clone()).expect("request"))
                .ok()
                .and_then(|r| r.best().cloned())
            else {
                t.row(&[name.to_string(), count.to_string(), "-".into(), "-".into(), "-".into()]);
                continue;
            };
            let per_gpu = best.cost.tokens_per_s / count as f64;
            let eff = match base_per_gpu {
                None => {
                    base_per_gpu = Some(per_gpu);
                    100.0
                }
                Some(b) => 100.0 * per_gpu / b,
            };
            t.row(&[
                name.to_string(),
                count.to_string(),
                format!("{:.0}", best.cost.tokens_per_s),
                format!("{per_gpu:.0}"),
                format!("{eff:.1}"),
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Fig. 9 — per-GPU throughput vs system scale (paper: decays with scale, faster for big models)",
        Some(std::path::Path::new("bench_out/fig9.csv")),
    );
}
