//! Service-layer throughput: cold vs cached request latency, single-flight
//! coalescing, and batch-mode requests/sec through the admission queue.
//!
//! The shape to reproduce: a cold request costs a full search (Table 1's
//! E2E column); a cached repeat costs microseconds (≥100× faster — the
//! service acceptance bar); a mixed batch of distinct requests scales with
//! the worker pool.

use astra::bench_util::{section, Bench};
use astra::coordinator::{EngineConfig, ScoringCore, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::service::{SearchService, ServiceConfig};
use std::time::Instant;

fn service() -> SearchService {
    SearchService::new(
        ScoringCore::new(
            GpuCatalog::builtin(),
            EngineConfig { use_forests: false, ..Default::default() },
        ),
        ServiceConfig::default(),
    )
}

fn req(model: &str, count: usize) -> SearchRequest {
    let m = ModelRegistry::builtin().get(model).unwrap().clone();
    SearchRequest::homogeneous("a800", count, m).expect("request")
}

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let mut bench = Bench::new();

    section("cold vs cached request latency");
    let svc = service();
    let cold_model = if fast { "llama2-7b" } else { "llama2-13b" };
    let r = req(cold_model, 64);
    let (cold, _) = bench.run_once(&format!("cold search {cold_model}@64"), || {
        svc.handle(&r).unwrap()
    });
    let cached = bench.run("cached repeat (same fingerprint)", || svc.handle(&r).unwrap());
    let speedup = cold.mean_secs() / cached.mean_secs().max(1e-12);
    println!("cache speedup: {speedup:.0}× (acceptance bar: ≥100×)");

    section("batch mode: distinct requests through the admission queue");
    let grid: Vec<(&str, usize)> = if fast {
        vec![("llama2-7b", 8), ("llama2-7b", 16), ("llama2-7b", 32), ("llama2-7b", 64)]
    } else {
        vec![
            ("llama2-7b", 8),
            ("llama2-7b", 16),
            ("llama2-7b", 32),
            ("llama2-7b", 64),
            ("llama2-13b", 16),
            ("llama2-13b", 32),
            ("llama3-8b", 16),
            ("llama3-8b", 32),
        ]
    };
    let reqs: Vec<SearchRequest> = grid.iter().map(|&(m, n)| req(m, n)).collect();

    let mut t = Table::new(&["phase", "requests", "secs", "req/s", "searches", "cache hits"]);
    // Cold fan-out: every request is a distinct fresh search.
    let cold_svc = service();
    let t0 = Instant::now();
    let out = cold_svc.handle_batch(&reqs);
    let cold_secs = t0.elapsed().as_secs_f64();
    assert!(out.iter().all(|r| r.is_ok()));
    t.row(&[
        "batch cold".into(),
        reqs.len().to_string(),
        format!("{cold_secs:.3}"),
        format!("{:.1}", reqs.len() as f64 / cold_secs),
        cold_svc.core().searches_run().to_string(),
        cold_svc.cache_stats().hits.to_string(),
    ]);
    // Warm fan-out: the same batch again is pure cache traffic.
    let t1 = Instant::now();
    let out = cold_svc.handle_batch(&reqs);
    let warm_secs = t1.elapsed().as_secs_f64();
    assert!(out.iter().all(|r| r.is_ok()));
    t.row(&[
        "batch warm".into(),
        reqs.len().to_string(),
        format!("{warm_secs:.6}"),
        format!("{:.0}", reqs.len() as f64 / warm_secs.max(1e-9)),
        cold_svc.core().searches_run().to_string(),
        cold_svc.cache_stats().hits.to_string(),
    ]);
    // Duplicate-heavy batch: single-flight dedup keeps searches at 1.
    let dup_svc = service();
    let dups: Vec<SearchRequest> = (0..reqs.len()).map(|_| req("llama2-7b", 64)).collect();
    let t2 = Instant::now();
    let out = dup_svc.handle_batch(&dups);
    let dup_secs = t2.elapsed().as_secs_f64();
    assert!(out.iter().all(|r| r.is_ok()));
    t.row(&[
        "batch all-duplicates".into(),
        dups.len().to_string(),
        format!("{dup_secs:.3}"),
        format!("{:.1}", dups.len() as f64 / dup_secs),
        dup_svc.core().searches_run().to_string(),
        dup_svc.cache_stats().hits.to_string(),
    ]);
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "service throughput — admission queue + result cache",
        Some(std::path::Path::new("bench_out/service_throughput.csv")),
    );

    println!("\n{}", bench.csv());
    println!("shape notes:");
    println!("  cold batch amortizes across workers; warm batch is lock+probe only;");
    println!("  all-duplicate batch must show searches=1 (single-flight).");
}
