//! Hot-path microbenchmarks — the EXPERIMENTS.md §Perf instrument.
//!
//! Measures each pipeline phase in isolation: enumeration, rule filtering,
//! memory filtering, native cost evaluation, feature packing, forest
//! inference, Eq. 22 composition, the discrete-event simulator, and the
//! hetero partition enumerators.

use astra::bench_util::{section, Bench};
use astra::cost::features::pack_batch;
use astra::cost::{pipeline_time, CostModel, EtaProvider};
use astra::gbdt::{EtaForests, FlatForest, FlatScratch, Forest, Tree};
use astra::prng::Rng;
use astra::gpu::GpuCatalog;
use astra::hetero::HeteroSolver;
use astra::memory::MemoryModel;
use astra::model::ModelRegistry;
use astra::rules::RuleSet;
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::{SearchSpace, SpaceConfig};

fn main() {
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get("llama2-7b").unwrap().clone();
    let space = SearchSpace::new(SpaceConfig::default());
    let rules = RuleSet::paper_defaults();
    let mem = MemoryModel::default();
    let mut bench = Bench::new();

    section("phase microbenchmarks — llama2-7b @ 64×a800");

    // Enumeration.
    let stats = bench.run("enumerate 64-gpu space", || {
        space.homogeneous(&model, &catalog, 1, 64).len()
    });
    let strategies = space.homogeneous(&model, &catalog, 1, 64);
    println!(
        "  → {} strategies, {:.0} strategies/s",
        strategies.len(),
        strategies.len() as f64 / stats.mean_secs()
    );

    // Rule filtering.
    let stats = bench.run("rule-filter all", || {
        strategies.iter().filter(|s| !rules.filters_out(*s).unwrap()).count()
    });
    println!("  → {:.0} rule-evals/s", strategies.len() as f64 / stats.mean_secs());

    // Memory filtering.
    let stats = bench.run("memory-filter all", || {
        strategies.iter().filter(|s| mem.fits(&model, s, &catalog)).count()
    });
    println!("  → {:.0} memory-evals/s", strategies.len() as f64 / stats.mean_secs());

    let valid: Vec<_> = strategies
        .iter()
        .filter(|s| !rules.filters_out(*s).unwrap() && mem.fits(&model, s, &catalog))
        .cloned()
        .collect();
    println!("  valid population: {}", valid.len());

    // Native cost evaluation (analytic and forest η).
    let cost_analytic = CostModel::new(catalog.clone(), EtaProvider::Analytic);
    let sample: Vec<_> = valid.iter().take(512).collect();
    let stats = bench.run("cost.evaluate ×512 (analytic η)", || {
        sample.iter().map(|s| cost_analytic.evaluate(&model, s).step_time).sum::<f64>()
    });
    println!("  → {:.0} evals/s", 512.0 / stats.mean_secs());

    if let Ok(f) = EtaForests::from_file(&astra::runtime::artifacts_dir().join("forest.json")) {
        let cost_forest = CostModel::new(catalog.clone(), EtaProvider::Forests(f));
        let stats = bench.run("cost.evaluate ×512 (forest η)", || {
            sample.iter().map(|s| cost_forest.evaluate(&model, s).step_time).sum::<f64>()
        });
        println!("  → {:.0} evals/s", 512.0 / stats.mean_secs());
    }

    // Forest inference: scalar per-row walk vs the flat level-synchronous
    // SoA batch kernel (the η hot path behind the cost memo). Synthetic
    // deterministic forest so the leg runs without trained artifacts;
    // predictions are asserted bit-identical before the timings count.
    let nf = astra::hw::COMP_FEATURES;
    let mut rng = Rng::new(0x0e7a_5eed);
    let trees: Vec<Tree> = (0..64)
        .map(|_| {
            let depth = 1 + rng.below(6) as usize;
            let internal = (1usize << depth) - 1;
            Tree {
                depth,
                feat: (0..internal).map(|_| rng.below(nf as u64) as u32).collect(),
                thresh: (0..internal).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect(),
                leaf: (0..1usize << depth).map(|_| rng.range_f64(0.05, 1.2) as f32).collect(),
            }
        })
        .collect();
    let eta_forest = Forest { trees, base: 0.3, lr: 0.05, n_features: nf };
    let flat = FlatForest::from_forest(&eta_forest);
    let rows = 16_384usize;
    let xs: Vec<f32> = (0..rows * nf).map(|_| rng.range_f64(-2.0, 12.0) as f32).collect();
    let scalar_stats = bench.run("forest.predict ×16384 (scalar walk)", || {
        xs.chunks_exact(nf).map(|row| eta_forest.predict(row) as f64).sum::<f64>()
    });
    let mut scratch = FlatScratch::default();
    let mut flat_out: Vec<f32> = Vec::new();
    let flat_stats = bench.run("flat.predict_batch ×16384 (SoA kernel)", || {
        flat_out.clear(); // predict_batch_with appends
        flat.predict_batch_with(&xs, nf, &mut scratch, &mut flat_out);
        flat_out.iter().map(|&v| v as f64).sum::<f64>()
    });
    for (i, row) in xs.chunks_exact(nf).enumerate() {
        assert_eq!(
            eta_forest.predict(row).to_bits(),
            flat_out[i].to_bits(),
            "row {i}: flat kernel diverged from the scalar walk"
        );
    }
    println!(
        "  → flat kernel speedup {:.2}× (bit-identical predictions)",
        scalar_stats.mean_secs() / flat_stats.mean_secs().max(1e-12)
    );

    // Feature packing (the HLO-engine feed path).
    let refs: Vec<&astra::strategy::ParallelStrategy> = valid.iter().take(256).collect();
    bench.run("pack_batch ×256", || pack_batch(&model, &refs, &catalog, 256).batch);

    // Eq. 22 composition alone.
    let totals: Vec<f64> = (0..64).map(|i| 0.01 + 1e-4 * i as f64).collect();
    bench.run("pipeline_time (64 stages) ×10k", || {
        let mut acc = 0.0;
        for _ in 0..10_000 {
            acc += pipeline_time(&totals, 128, 1);
        }
        acc
    });

    // Discrete-event simulator.
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let s = &valid[0];
    bench.run("simulator.measure (1 strategy)", || sim.measure(&model, s).step_time);

    // Hetero enumerators.
    let budgets = HeteroSolver::budgets(
        &catalog,
        &[(catalog.find("a800").unwrap(), 96), (catalog.find("h100").unwrap(), 96)],
        2,
        4,
    );
    let solver = HeteroSolver::default();
    bench.run("hetero exhaustive (N=32,P=8)", || solver.enumerate_exhaustive(32, 8, &budgets).len());
    bench.run("hetero pruned (N=32,P=8)", || solver.enumerate_pruned(32, 8, &budgets).len());

    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/perf_hotpath.csv", bench.csv()).ok();
    println!("\n(csv: bench_out/perf_hotpath.csv)");
}
