//! Table 2 — heterogeneous vs single-GPU-type optimal throughput @1024 GPUs.
//!
//! Paper shape: H100 > H800 > heterogeneous(A800+H100) > A800 for every
//! model — mixing cannot beat the best pure type at equal count, but lands
//! well above the slow type.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::strategy::GpuPoolMode;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let count = 1024usize;
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let engine = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let a800 = catalog.find("a800").unwrap();
    let h100 = catalog.find("h100").unwrap();

    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-13b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
    };

    let mut t = Table::new(&["Model", "H100", "H800", "A800", "Heter."]);
    let mut shape_ok = 0usize;
    let mut rows = 0usize;
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        let pure = |gpu: &str| -> f64 {
            engine
                .search(&SearchRequest::homogeneous(gpu, count, model.clone()).expect("request"))
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s))
                .unwrap_or(0.0)
        };
        let th100 = pure("h100");
        let th800 = pure("h800");
        let ta800 = pure("a800");
        let theter = engine
            .search(&SearchRequest {
                mode: GpuPoolMode::Heterogeneous {
                    total: count,
                    caps: vec![(a800, count * 3 / 4), (h100, count * 3 / 4)],
                },
                model: model.clone(),
            })
            .ok()
            .and_then(|r| r.best().map(|b| b.cost.tokens_per_s))
            .unwrap_or(0.0);
        rows += 1;
        if th100 >= th800 && th800 >= theter && theter >= ta800 * 0.98 {
            shape_ok += 1;
        }
        t.row(&[
            name.to_string(),
            format!("{th100:.0}"),
            format!("{th800:.0}"),
            format!("{ta800:.0}"),
            format!("{theter:.0}"),
        ]);
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Table 2 — hetero vs single-type optimal throughput @1024 GPUs (tokens/s)",
        Some(std::path::Path::new("bench_out/table2.csv")),
    );
    println!("\nshape (H100 ≥ H800 ≥ Heter ≥ A800) holds in {shape_ok}/{rows} rows");
    println!("paper example (Llama-2-7B): 10.1M / 9.0M / 4.0M(A800) / 5.2M(Heter)");
}
