//! Ablation (ours) — native rust scorer vs the AOT HLO scorer (Layer-1
//! Pallas kernels through PJRT), plus the analytic-η vs GBDT-η variants.
//!
//! Measures scoring throughput (strategies/s) and re-verifies numeric
//! parity on the fly. The HLO path exists to prove the three-layer
//! architecture end-to-end; the native path is the production fast path
//! (see EXPERIMENTS.md §Perf).

use astra::bench_util::{section, Bench};
use astra::coordinator::{AstraEngine, EngineConfig, ScoringEngine, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;

fn main() {
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get("llama2-7b").unwrap().clone();
    let req = SearchRequest::homogeneous("a800", 64, model.clone()).expect("request");

    let mut variants: Vec<(&str, AstraEngine)> = vec![
        (
            "native+forest",
            AstraEngine::new(catalog.clone(), EngineConfig::default()),
        ),
        (
            "native+analytic",
            AstraEngine::new(
                catalog.clone(),
                EngineConfig { use_forests: false, ..Default::default() },
            ),
        ),
    ];
    if astra::runtime::artifacts_present() {
        variants.push((
            "hlo(pallas)",
            AstraEngine::new(
                catalog.clone(),
                EngineConfig { engine: ScoringEngine::Hlo, ..Default::default() },
            ),
        ));
    } else {
        println!("NOTE: artifacts missing; hlo variant skipped (run `make artifacts`)");
    }

    section("scoring engine ablation — llama2-7b @ 64×a800");
    let mut bench = Bench::new();
    let mut t = Table::new(&["engine", "scored", "sim time", "strategies/s", "best step"]);
    let mut steps: Vec<(String, f64)> = Vec::new();
    for (name, eng) in &variants {
        let stats = bench.run(&format!("search:{name}"), || eng.search(&req).unwrap());
        let rep = eng.search(&req).unwrap();
        let best = rep.best().unwrap().cost.step_time;
        steps.push((name.to_string(), best));
        t.row(&[
            name.to_string(),
            rep.scored.to_string(),
            format!("{:.4}s", rep.simulate_secs),
            format!("{:.0}", rep.scored as f64 / rep.simulate_secs),
            format!("{best:.4}s"),
        ]);
        let _ = stats;
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit("engine comparison", Some(std::path::Path::new("bench_out/ablation_engine.csv")));

    // Parity: native+forest and hlo must agree on the winner's step time.
    if let (Some((_, a)), Some((_, b))) = (
        steps.iter().find(|(n, _)| n == "native+forest"),
        steps.iter().find(|(n, _)| n == "hlo(pallas)"),
    ) {
        let rel = (a - b).abs() / a;
        println!("\nnative↔hlo winner parity: rel diff {rel:.2e}");
        assert!(rel < 0.02, "engines diverged");
    }
    println!("{}", bench.csv());
}
