//! Fig. 7 extension — the heterogeneous "optimal line": Pareto frontier of
//! throughput vs money over *mixed* GPU pools, and the branch-and-bound
//! ablation (pruned vs unpruned search time, identical selections).
//!
//! The money-saving crossover the search exists for: h100s are the cheapest
//! per effective FLOP here, a800s the cheapest per hour — under a tight
//! budget the winning pool mixes them.

use astra::bench_util::{section, Bench};
use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::MoneyModel;
use astra::pricing::PriceBook;
use astra::report::Table;
use astra::strategy::GpuPoolMode;

fn engine(prune: bool, spot: bool) -> AstraEngine {
    let mut book = PriceBook::builtin();
    book.use_spot = spot;
    AstraEngine::new(
        GpuCatalog::builtin(),
        EngineConfig {
            money: MoneyModel { train_tokens: 1e9, book },
            money_prune: prune,
            ..Default::default()
        },
    )
}

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get("llama2-7b").unwrap().clone();
    let cap = if fast { 16 } else { 64 };
    let caps = vec![
        (catalog.find("a800").unwrap(), cap),
        (catalog.find("h100").unwrap(), cap),
    ];

    // Learn the cost scale from a free run, then pick a tight budget.
    let free = engine(true, false)
        .search(&SearchRequest {
            mode: GpuPoolMode::HeteroCost { caps: caps.clone(), max_money: f64::INFINITY },
            model: model.clone(),
        })
        .unwrap();
    assert!(free.pool.is_valid_frontier(), "frontier invariant violated");
    let cheap = free.pool.entries().last().unwrap().cost;
    let budget = cheap * 1.2;

    section("hetero money frontier (free budget)");
    let mut t = Table::new(&["tokens/s", "run cost USD"]);
    for e in free.pool.entries() {
        t.row(&[format!("{:.0}", e.throughput), format!("{:.2}", e.cost)]);
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        &format!("Fig. 7 hetero — optimal line, llama2-7b on ≤{cap}×a800 + ≤{cap}×h100, 1e9 tokens"),
        Some(std::path::Path::new("bench_out/fig7_hetero_money.csv")),
    );

    section(&format!("branch-and-bound ablation (budget ${budget:.0})"));
    let mut b = Bench::new();
    let req = |max_money: f64| SearchRequest {
        mode: GpuPoolMode::HeteroCost { caps: caps.clone(), max_money },
        model: model.clone(),
    };
    let pruned_eng = engine(true, false);
    let unpruned_eng = engine(false, false);
    let pruned = b.run("hetero-cost pruned", || pruned_eng.search(&req(budget)).unwrap());
    let unpruned = b.run("hetero-cost unpruned", || unpruned_eng.search(&req(budget)).unwrap());

    let rep_p = pruned_eng.search(&req(budget)).unwrap();
    let rep_u = unpruned_eng.search(&req(budget)).unwrap();
    println!(
        "pruned: {} generated, {} pools skipped | unpruned: {} generated, {} skipped",
        rep_p.generated, rep_p.pruned_pools, rep_u.generated, rep_u.pruned_pools
    );
    // Soundness: the budget-optimal pick is identical either way.
    let pick = |r: &astra::coordinator::SearchReport| {
        r.pool.best_within_budget(budget).map(|e| (e.throughput, e.cost))
    };
    let (pp, pu) = (pick(&rep_p), pick(&rep_u));
    match (pp, pu) {
        (Some((tp, cp)), Some((tu, cu))) => {
            assert!(
                (tp - tu).abs() < 1e-6 && (cp - cu).abs() < 1e-6,
                "pruned pick ({tp:.1}, ${cp:.2}) != unpruned ({tu:.1}, ${cu:.2})"
            );
        }
        (None, None) => {}
        other => panic!("pruned/unpruned disagree on feasibility: {other:?}"),
    }
    println!(
        "speedup from pruning: {:.2}× (mean {:.3}s → {:.3}s)",
        unpruned.mean_secs() / pruned.mean_secs().max(1e-12),
        unpruned.mean_secs(),
        pruned.mean_secs()
    );

    section("spot vs on-demand selection");
    let spot_rep = engine(true, true).search(&req(budget)).unwrap();
    match (free.pool.best_within_budget(budget), spot_rep.pool.best_within_budget(budget)) {
        (Some(od), Some(sp)) => println!(
            "on-demand pick: {:.0} tok/s ${:.0} | spot pick: {:.0} tok/s ${:.0}",
            od.throughput, od.cost, sp.throughput, sp.cost
        ),
        _ => println!("budget infeasible under one of the rate cards"),
    }
    std::fs::write("bench_out/fig7_hetero_money_bench.csv", b.csv()).ok();
}
