//! Figure 7 — the "optimal line": Pareto frontier of throughput vs money.
//!
//! Mode-3 sweep over GPU counts and types; prints the frontier (throughput
//! strictly increasing with cost along the line — the monotone shape the
//! paper plots) and sample budget selections.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::MoneyModel;
use astra::report::Table;
use astra::strategy::GpuPoolMode;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { money: MoneyModel { train_tokens: 1e9, ..Default::default() }, ..Default::default() },
    );

    // Paper's search pools: H100, A800, H800.
    let gpus: &[&str] = if fast { &["h100"] } else { &["h100", "a800", "h800"] };
    let model = registry.get("llama2-7b").unwrap().clone();
    let max_count = if fast { 128 } else { 1024 };

    for gpu_name in gpus {
        let gpu = catalog.find(gpu_name).unwrap();
        let rep = engine
            .search(&SearchRequest {
                mode: GpuPoolMode::Cost { gpu, max_count, max_money: f64::INFINITY },
                model: model.clone(),
            })
            .unwrap();
        let mut t = Table::new(&["tokens/s", "run cost USD"]);
        for e in rep.pool.entries() {
            t.row(&[format!("{:.0}", e.throughput), format!("{:.2}", e.cost)]);
        }
        std::fs::create_dir_all("bench_out").ok();
        t.emit(
            &format!("Fig. 7 — optimal line, llama2-7b on {gpu_name} (≤{max_count} GPUs, 1e9 tokens)"),
            Some(std::path::Path::new(&format!("bench_out/fig7_{gpu_name}.csv"))),
        );
        assert!(rep.pool.is_valid_frontier(), "frontier invariant violated");
        // Budget sampling: the selection respects Eq. 33.
        if let (Some(first), Some(last)) = (rep.pool.entries().first(), rep.pool.entries().last()) {
            for frac in [0.25, 0.5, 1.0] {
                let budget = last.cost + (first.cost - last.cost) * frac;
                if let Some(pick) = rep.pool.best_within_budget(budget) {
                    println!(
                        "  budget ${budget:.0} → {:.0} tokens/s for ${:.0}",
                        pick.throughput, pick.cost
                    );
                }
            }
        }
    }
}
