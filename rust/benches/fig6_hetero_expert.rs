//! Figure 6 — heterogeneous (A800+H100) Astra vs expert throughput.
//!
//! Paper setup: mixed clusters of {64, 256, 1024, 4096} GPUs; six experts
//! craft heterogeneous plans (stage/layer splits by hand) vs Astra's Eq. 23
//! search. Shape: Astra wins clearly — manual layer splitting is the hard
//! part of heterogeneous training.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::expert::ExpertPanel;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::GpuPoolMode;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let engine = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let panel = ExpertPanel::default();
    let a800 = catalog.find("a800").unwrap();
    let h100 = catalog.find("h100").unwrap();

    let counts: &[usize] = if fast { &[64] } else { &[64, 256, 1024, 4096] };
    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-13b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
    };

    let mut t =
        Table::new(&["Model", "#GPU", "expert tokens/s", "astra tokens/s", "speedup"]);
    let mut wins = 0usize;
    let mut cells = 0usize;
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        for &count in counts {
            let caps = vec![(a800, count * 3 / 4), (h100, count * 3 / 4)];
            let Ok(rep) = engine.search(&SearchRequest {
                mode: GpuPoolMode::Heterogeneous { total: count, caps: caps.clone() },
                model: model.clone(),
            }) else {
                continue;
            };
            let Some(best) = rep.best() else { continue };
            let astra_tput = sim.measure(&model, &best.strategy).tokens_per_s;
            let expert_tput = panel
                .proposals_hetero(&model, &catalog, &caps, count)
                .iter()
                .map(|(_, s)| sim.measure(&model, s).tokens_per_s)
                .fold(0.0f64, f64::max);
            if expert_tput == 0.0 {
                continue;
            }
            cells += 1;
            let speedup = astra_tput / expert_tput;
            if speedup >= 0.999 {
                wins += 1;
            }
            t.row(&[
                name.to_string(),
                count.to_string(),
                format!("{expert_tput:.0}"),
                format!("{astra_tput:.0}"),
                format!("{speedup:.3}×"),
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Fig. 6 — Astra vs experts, heterogeneous A800+H100 (simulated execution)",
        Some(std::path::Path::new("bench_out/fig6.csv")),
    );
    println!("\nAstra ≥ expert in {wins}/{cells} heterogeneous settings (paper: Astra wins clearly)");
}
