//! Ablation (ours) — pruned vs exhaustive heterogeneous layer-assignment
//! solver (DESIGN.md §4 `hetero/`).
//!
//! The paper enumerates all `O(N^{M−1}·P^{M−1})` Eq. 23 solutions; our
//! pruned solver seeds layer counts ∝ GPU speed and searches a ±2 box.
//! This bench quantifies the trade: candidate count, wall time, and the
//! optimality gap of the found optimum.

use astra::bench_util::{fmt_dur, section};
use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::strategy::GpuPoolMode;
use std::time::Instant;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let a800 = catalog.find("a800").unwrap();
    let h100 = catalog.find("h100").unwrap();

    let pruned = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let exhaustive = AstraEngine::new(
        catalog.clone(),
        EngineConfig { hetero_exhaustive: true, ..Default::default() },
    );

    let settings: &[(&str, usize)] = if fast {
        &[("llama2-7b", 32)]
    } else {
        &[("llama2-7b", 32), ("llama2-7b", 64), ("llama2-13b", 64), ("llama2-70b", 128)]
    };

    section("pruned vs exhaustive Eq. 23 solver");
    let mut t = Table::new(&[
        "Model",
        "#GPU",
        "exhaustive cand",
        "pruned cand",
        "exhaustive time",
        "pruned time",
        "tput gap",
    ]);
    for &(name, count) in settings {
        let model = registry.get(name).unwrap().clone();
        let req = SearchRequest {
            mode: GpuPoolMode::Heterogeneous {
                total: count,
                caps: vec![(a800, count * 3 / 4), (h100, count * 3 / 4)],
            },
            model,
        };
        let t0 = Instant::now();
        let full = exhaustive.search(&req).unwrap();
        let full_time = t0.elapsed();
        let t1 = Instant::now();
        let fastr = pruned.search(&req).unwrap();
        let fast_time = t1.elapsed();
        let gap = fastr.best().unwrap().cost.tokens_per_s / full.best().unwrap().cost.tokens_per_s;
        t.row(&[
            name.to_string(),
            count.to_string(),
            full.generated.to_string(),
            fastr.generated.to_string(),
            fmt_dur(full_time),
            fmt_dur(fast_time),
            format!("{gap:.4}×"),
        ]);
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "hetero solver ablation (gap 1.0 = pruned finds the exhaustive optimum)",
        Some(std::path::Path::new("bench_out/ablation_hetero.csv")),
    );
}
