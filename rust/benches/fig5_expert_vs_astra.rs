//! Figure 5 — Astra-searched vs expert-optimal throughput (homogeneous).
//!
//! Paper setup: 7 models × GPU counts {32, 128, 256, 1024}, six experts per
//! setting, best expert plan vs Astra's searched plan, all *executed* (here:
//! on the discrete-event simulator). Shape to hold: Astra matches or beats
//! the expert-optimal in (nearly) every cell.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::expert::ExpertPanel;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::simulator::{PipelineSimulator, SimConfig};

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let engine = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let panel = ExpertPanel::default();
    let a800 = catalog.find("a800").unwrap();

    let counts: &[usize] = if fast { &[32, 128] } else { &[32, 128, 256, 1024] };
    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-13b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
    };

    let mut t = Table::new(&["Model", "#GPU", "expert tokens/s", "astra tokens/s", "speedup", "expert used"]);
    let mut wins = 0usize;
    let mut cells = 0usize;
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        for &count in counts {
            let rep = engine
                .search(&SearchRequest::homogeneous("a800", count, model.clone()).expect("request"))
                .unwrap();
            let Some(best) = rep.best() else {
                continue;
            };
            let astra_tput = sim.measure(&model, &best.strategy).tokens_per_s;
            let mut expert_best = 0.0f64;
            let mut expert_name = "-";
            for (p, s) in panel.proposals(&model, &catalog, a800, count) {
                let tput = sim.measure(&model, &s).tokens_per_s;
                if tput > expert_best {
                    expert_best = tput;
                    expert_name = p.name();
                }
            }
            if expert_best == 0.0 {
                continue;
            }
            cells += 1;
            let speedup = astra_tput / expert_best;
            if speedup >= 0.999 {
                wins += 1;
            }
            t.row(&[
                name.to_string(),
                count.to_string(),
                format!("{expert_best:.0}"),
                format!("{astra_tput:.0}"),
                format!("{speedup:.3}×"),
                expert_name.to_string(),
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Fig. 5 — Astra vs best-of-six-experts, homogeneous A800 (simulated execution)",
        Some(std::path::Path::new("bench_out/fig5.csv")),
    );
    println!("\nAstra ≥ expert in {wins}/{cells} settings (paper: matches or exceeds everywhere)");
}
