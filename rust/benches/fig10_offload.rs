//! Figure 10 (appendix B.4) — memory offloading enabled vs disabled.
//!
//! Paper shape: negligible for the small models, increasingly important for
//! the big ones (offload frees optimizer memory, unlocking better-shaped
//! strategies that outweigh the PCIe traffic).

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::strategy::SpaceConfig;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let with_off = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let no_off = AstraEngine::new(
        catalog.clone(),
        EngineConfig { space: SpaceConfig::no_offload(), ..Default::default() },
    );

    let counts: &[usize] = if fast { &[64, 256] } else { &[64, 256, 1024] };
    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-70b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "glm-130b"]
    };

    let mut t = Table::new(&["Model", "#GPU", "no-offload tokens/s", "offload-allowed tokens/s", "gain"]);
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        for &count in counts {
            let req = SearchRequest::homogeneous("a800", count, model.clone()).expect("request");
            let off = with_off
                .search(&req)
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s))
                .unwrap_or(0.0);
            let non = no_off
                .search(&req)
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s));
            t.row(&[
                name.to_string(),
                count.to_string(),
                non.map(|v| format!("{v:.0}")).unwrap_or_else(|| "OOM".into()),
                format!("{off:.0}"),
                non.map(|v| format!("{:.3}×", off / v)).unwrap_or_else(|| "∞".into()),
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Fig. 10 — offload allowed vs disallowed (paper: matters more as models grow)",
        Some(std::path::Path::new("bench_out/fig10.csv")),
    );
}
