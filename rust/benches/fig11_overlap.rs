//! Figure 11 (appendix B.5) — communication overlap enabled vs disabled.
//!
//! Paper shape: overlap (grad-reduce, param-gather, p2p, TP) always helps,
//! modestly for small models and strongly for big models / large scales
//! where communication is the bottleneck.

use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::Table;
use astra::strategy::SpaceConfig;

fn main() {
    let fast = std::env::var("ASTRA_BENCH_FAST").as_deref() == Ok("1");
    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let overlap = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let no_overlap = AstraEngine::new(
        catalog.clone(),
        EngineConfig { space: SpaceConfig::no_overlap(), ..Default::default() },
    );

    let counts: &[usize] = if fast { &[64, 256] } else { &[64, 256, 1024] };
    let models: Vec<&str> = if fast {
        vec!["llama2-7b", "llama2-70b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "glm-130b"]
    };

    let mut t = Table::new(&["Model", "#GPU", "no-overlap tokens/s", "overlap tokens/s", "gain"]);
    let mut monotone = true;
    for name in &models {
        let model = registry.get(name).unwrap().clone();
        for &count in counts {
            let req = SearchRequest::homogeneous("a800", count, model.clone()).expect("request");
            let on = overlap
                .search(&req)
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s))
                .unwrap_or(0.0);
            let off = no_overlap
                .search(&req)
                .ok()
                .and_then(|r| r.best().map(|b| b.cost.tokens_per_s))
                .unwrap_or(0.0);
            if on + 1e-9 < off {
                monotone = false;
            }
            t.row(&[
                name.to_string(),
                count.to_string(),
                format!("{off:.0}"),
                format!("{on:.0}"),
                if off > 0.0 { format!("{:.3}×", on / off) } else { "-".into() },
            ]);
        }
    }
    std::fs::create_dir_all("bench_out").ok();
    t.emit(
        "Fig. 11 — communication overlap on vs off (paper: always ≥1×, larger for big models)",
        Some(std::path::Path::new("bench_out/fig11.csv")),
    );
    println!("\noverlap never hurts: {monotone}");
}
