"""Price-book loader — python mirror of ``rust/src/pricing/mod.rs``.

Reads the same ``data/price_book.json`` rate card the rust search engine
uses for the money-saving modes, so offline tooling (GBDT training-set
cost labels, notebook analyses) prices pools identically to the serving
path. The semantics MUST stay in lockstep with the rust side:

* entries key by GPU *name*, sorted, duplicates replaced on upsert;
* effective rate = (spot if ``use_spot`` else on-demand) × the
  time-of-day multiplier of ``hour`` (flat ``1.0`` when unset);
* missing ``spot_per_hour`` defaults to the on-demand rate; missing
  ``tod_multipliers`` default to 24×1.0.

``python/tests/test_pricing.py`` pins the file against ``hw_profile.json``
(every GPU priced, on-demand matching the catalog's ``price_per_hour``).
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field

_BOOK_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "data",
    "price_book.json",
)


@dataclass
class PriceEntry:
    gpu: str
    on_demand_per_hour: float
    spot_per_hour: float


@dataclass
class PriceBook:
    entries: list[PriceEntry] = field(default_factory=list)
    tod_multipliers: list[float] = field(default_factory=lambda: [1.0] * 24)
    use_spot: bool = False
    hour: int | None = None

    def get(self, gpu_name: str) -> PriceEntry | None:
        for e in self.entries:
            if e.gpu == gpu_name:
                return e
        return None

    def tod_multiplier(self) -> float:
        # Rust does `get(h).unwrap_or(1.0)`: out-of-range hours price flat
        # (no python negative-index wraparound, no IndexError).
        if self.hour is None or not 0 <= self.hour < len(self.tod_multipliers):
            return 1.0
        return self.tod_multipliers[self.hour]

    def rate_per_hour(self, gpu_name: str) -> float | None:
        e = self.get(gpu_name)
        if e is None:
            return None
        base = e.spot_per_hour if self.use_spot else e.on_demand_per_hour
        return base * self.tod_multiplier()

    def rate_per_second(self, gpu_name: str) -> float | None:
        r = self.rate_per_hour(gpu_name)
        return None if r is None else r / 3600.0

    def validate(self) -> None:
        # Mirrors the rust `PriceBook::validate`: rates must be finite and
        # positive (json.load happily parses `Infinity`/`NaN`), spot ≤
        # on-demand, exactly 24 positive finite multipliers, hour in range.
        for e in self.entries:
            if not (math.isfinite(e.on_demand_per_hour) and e.on_demand_per_hour > 0.0):
                raise ValueError(f"{e.gpu}: bad on-demand rate {e.on_demand_per_hour}")
            if not (math.isfinite(e.spot_per_hour) and e.spot_per_hour > 0.0):
                raise ValueError(f"{e.gpu}: bad spot rate {e.spot_per_hour}")
            if e.spot_per_hour > e.on_demand_per_hour:
                raise ValueError(f"{e.gpu}: spot rate exceeds on-demand")
        if len(self.tod_multipliers) != 24:
            raise ValueError(f"{len(self.tod_multipliers)} tod multipliers (need 24)")
        if any(not (math.isfinite(m) and m > 0.0) for m in self.tod_multipliers):
            raise ValueError("non-positive tod multiplier")
        if self.hour is not None and not 0 <= self.hour < 24:
            raise ValueError(f"hour {self.hour} out of range")


def load_price_book(path: str = _BOOK_PATH) -> PriceBook:
    """Load ``data/price_book.json`` (the rust side reads the same file)."""
    with open(path) as f:
        raw = json.load(f)
    book = PriceBook()
    for g in raw["gpus"]:
        on_demand = float(g["on_demand_per_hour"])
        book.entries.append(
            PriceEntry(
                gpu=g["name"],
                on_demand_per_hour=on_demand,
                spot_per_hour=float(g.get("spot_per_hour", on_demand)),
            )
        )
    book.entries.sort(key=lambda e: e.gpu)
    if "tod_multipliers" in raw:
        book.tod_multipliers = [float(m) for m in raw["tod_multipliers"]]
    book.validate()
    return book
