"""Hardware-truth efficiency curves and the synthetic profiling dataset.

Python mirror of ``rust/src/hw/mod.rs`` — the formulas MUST stay in lockstep
(the rust test ``crosscheck_hw.rs`` compares against samples exported to
``artifacts/eff_samples.json`` by ``aot.py``).

This module replaces the paper's offline cluster profiling runs: it samples
the hardware-truth surfaces over the operating range of the cost model
(GEMM sizes, collective sizes, all GPU types) with multiplicative
measurement noise, producing the training set for the GBDT η predictors.
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass

import numpy as np

COMP_FEATURES = 6
COMM_FEATURES = 4

_PROFILE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "data",
    "hw_profile.json",
)


@dataclass
class GpuProfile:
    name: str
    mem_gib: float
    peak_tflops_bf16: float
    hbm_gbs: float
    nvlink_gbs: float
    internode_gbs: float
    pcie_gbs: float
    price_per_hour: float
    util_max: float
    launch_overhead_s: float
    skinny_dim: float
    skinny_penalty: float
    mem_bound_intensity: float
    comm_latency_s: float
    comm_eff_max: float

    @property
    def peak_flops(self) -> float:
        return self.peak_tflops_bf16 * 1e12


def load_profiles(path: str = _PROFILE_PATH) -> list[GpuProfile]:
    """Load ``data/hw_profile.json`` (the rust side reads the same file)."""
    with open(path) as f:
        raw = json.load(f)
    out = []
    for g in raw["gpus"]:
        e = g["eff"]
        out.append(
            GpuProfile(
                name=g["name"],
                mem_gib=g["mem_gib"],
                peak_tflops_bf16=g["peak_tflops_bf16"],
                hbm_gbs=g["hbm_gbs"],
                nvlink_gbs=g["nvlink_gbs"],
                internode_gbs=g["internode_gbs"],
                pcie_gbs=g["pcie_gbs"],
                price_per_hour=g["price_per_hour"],
                util_max=e["util_max"],
                launch_overhead_s=e["launch_overhead_s"],
                skinny_dim=e["skinny_dim"],
                skinny_penalty=e["skinny_penalty"],
                mem_bound_intensity=e["mem_bound_intensity"],
                comm_latency_s=e["comm_latency_s"],
                comm_eff_max=e["comm_eff_max"],
            )
        )
    return out


def eta_comp(g: GpuProfile, flops: float, min_dim: float, intensity: float) -> float:
    """Ground-truth computation efficiency (mirror of hw::eta_comp)."""
    f_half = g.peak_flops * g.launch_overhead_s
    sat = flops / (flops + f_half)
    if min_dim >= g.skinny_dim:
        skinny = 1.0
    else:
        skinny = g.skinny_penalty + (1.0 - g.skinny_penalty) * (min_dim / g.skinny_dim)
    roof = min(intensity / g.mem_bound_intensity, 1.0)
    return float(np.clip(g.util_max * sat * skinny * roof, 1e-4, 1.0))


def eta_comm(g: GpuProfile, bytes_: float, bw_gbs: float, participants: float) -> float:
    """Ground-truth communication efficiency (mirror of hw::eta_comm)."""
    b_half = bw_gbs * 1e9 * g.comm_latency_s * max(participants, 1.0)
    sat = bytes_ / (bytes_ + b_half)
    return float(np.clip(g.comm_eff_max * sat, 1e-4, 1.0))


def comp_features(g: GpuProfile, flops: float, min_dim: float, intensity: float) -> list[float]:
    """Mirror of hw::comp_features — feature layout for the comp forest."""
    return [
        math.log10(max(flops, 1.0)),
        math.log10(max(min_dim, 1.0)),
        math.log10(max(intensity, 1e-3)),
        g.peak_tflops_bf16 / 1000.0,
        g.hbm_gbs / 1000.0,
        g.util_max,
    ]


def comm_features(g: GpuProfile, bytes_: float, bw_gbs: float, participants: float) -> list[float]:
    """Mirror of hw::comm_features."""
    return [
        math.log10(max(bytes_, 1.0)),
        math.log10(max(bw_gbs, 1e-3)),
        math.log10(max(participants, 1.0)),
        g.comm_eff_max,
    ]


def sample_comp_dataset(
    profiles: list[GpuProfile], n_per_gpu: int = 4000, noise: float = 0.01, seed: int = 7
) -> tuple[np.ndarray, np.ndarray]:
    """Noisy samples of the η_comp surface over the cost model's operating
    range (the synthetic stand-in for the paper's profiling runs)."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for g in profiles:
        log_flops = rng.uniform(6.0, 15.0, n_per_gpu)
        log_dim = rng.uniform(0.0, 4.2, n_per_gpu)
        log_int = rng.uniform(0.0, 3.8, n_per_gpu)
        for lf, ld, li in zip(log_flops, log_dim, log_int):
            flops, dim, inten = 10.0**lf, 10.0**ld, 10.0**li
            y = eta_comp(g, flops, dim, inten) * float(np.exp(noise * rng.standard_normal()))
            xs.append(comp_features(g, flops, dim, inten))
            ys.append(min(y, 1.0))
    return np.asarray(xs, dtype=np.float32), np.asarray(ys, dtype=np.float32)


def sample_comm_dataset(
    profiles: list[GpuProfile], n_per_gpu: int = 3000, noise: float = 0.01, seed: int = 8
) -> tuple[np.ndarray, np.ndarray]:
    """Noisy samples of the η_comm surface."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for g in profiles:
        log_bytes = rng.uniform(3.0, 11.0, n_per_gpu)
        bws = rng.choice(
            [g.nvlink_gbs, g.internode_gbs, g.pcie_gbs], size=n_per_gpu
        )
        log_n = rng.uniform(np.log10(2.0), np.log10(512.0), n_per_gpu)
        for lb, bw, ln in zip(log_bytes, bws, log_n):
            byts, n = 10.0**lb, 10.0**ln
            y = eta_comm(g, byts, bw, n) * float(np.exp(noise * rng.standard_normal()))
            xs.append(comm_features(g, byts, bw, n))
            ys.append(min(y, 1.0))
    return np.asarray(xs, dtype=np.float32), np.asarray(ys, dtype=np.float32)


def export_crosscheck_samples(profiles: list[GpuProfile], n: int = 64, seed: int = 13) -> dict:
    """Deterministic (noise-free) samples for the rust↔python lockstep test
    (written to artifacts/eff_samples.json by aot.py)."""
    rng = np.random.default_rng(seed)
    comp, comm = [], []
    for g in profiles:
        for _ in range(n):
            flops = 10.0 ** rng.uniform(6.0, 15.0)
            dim = 10.0 ** rng.uniform(0.0, 4.2)
            inten = 10.0 ** rng.uniform(0.0, 3.8)
            comp.append(
                {
                    "gpu": g.name,
                    "flops": flops,
                    "min_dim": dim,
                    "intensity": inten,
                    "eta": eta_comp(g, flops, dim, inten),
                    "features": comp_features(g, flops, dim, inten),
                }
            )
            byts = 10.0 ** rng.uniform(3.0, 11.0)
            bw = float(rng.choice([g.nvlink_gbs, g.internode_gbs, g.pcie_gbs]))
            parts = 10.0 ** rng.uniform(np.log10(2.0), np.log10(512.0))
            comm.append(
                {
                    "gpu": g.name,
                    "bytes": byts,
                    "bw_gbs": bw,
                    "participants": parts,
                    "eta": eta_comm(g, byts, bw, parts),
                    "features": comm_features(g, byts, bw, parts),
                }
            )
    return {"comp": comp, "comm": comm}
