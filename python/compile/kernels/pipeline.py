"""Layer-1 Pallas kernel: batched heterogeneous pipeline-time evaluation.

Implements the paper's Eq. 22 — `Σᵢ(tᵢ+hᵢ) + (K−1)·maxᵢ(tᵢ+hᵢ)` — in the
interleaving-corrected form `K·max + (Σ−max)/vpp`, masked over padded stage
slots, for a whole batch of candidate strategies at once.

TPU adaptation: one grid step per ``BLOCK_B`` strategies; the [block, PMAX]
stage-time tile lives in VMEM and the reduction runs on the VPU lanes.
``interpret=True`` (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 256


def _pipeline_kernel(totals_ref, mask_ref, k_ref, vpp_ref, o_ref):
    totals = totals_ref[...] * mask_ref[...]  # [block, P]
    s = totals.sum(axis=1)
    m = totals.max(axis=1)
    o_ref[...] = k_ref[...] * m + (s - m) / vpp_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def pipeline_eval(totals, mask, k, vpp, block_b: int = BLOCK_B):
    """Eq. 22 over a batch: totals/mask f32[B, P], k/vpp f32[B] → f32[B]."""
    import math

    b, p = totals.shape
    block = math.gcd(b, block_b)
    grid = (b // block,)
    return pl.pallas_call(
        _pipeline_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, p), lambda i: (i, 0)),
            pl.BlockSpec((block, p), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(totals, mask, k, vpp)
