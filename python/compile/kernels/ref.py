"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness references: ``pytest`` compares every kernel output
against these under hypothesis-driven shape/value sweeps
(``python/tests/test_kernel.py``).
"""

from __future__ import annotations

import jax.numpy as jnp


def forest_ref(x, feat, thresh, leaf):
    """Reference GBDT forest inference.

    x      : f32[N, F]   feature rows
    feat   : i32[T, I]   feature index per internal node (complete trees)
    thresh : f32[T, I]   split thresholds
    leaf   : f32[T, L]   leaf values, L = I + 1 = 2^depth
    returns: f32[N]      sum over trees of the reached leaf value
    """
    n = x.shape[0]
    t = feat.shape[0]
    internal = feat.shape[1]
    depth = (internal + 1).bit_length() - 1
    idx = jnp.zeros((n, t), dtype=jnp.int32)
    for _ in range(depth):
        f = jnp.take_along_axis(feat[None, :, :].repeat(n, axis=0), idx[:, :, None], axis=2)[
            :, :, 0
        ]
        th = jnp.take_along_axis(thresh[None, :, :].repeat(n, axis=0), idx[:, :, None], axis=2)[
            :, :, 0
        ]
        xv = jnp.take_along_axis(x, f, axis=1)  # [N, T]
        idx = 2 * idx + 1 + (xv >= th).astype(jnp.int32)
    leaf_idx = idx - internal
    vals = jnp.take_along_axis(leaf[None, :, :].repeat(n, axis=0), leaf_idx[:, :, None], axis=2)[
        :, :, 0
    ]
    return vals.sum(axis=1)


def pipeline_ref(totals, mask, k, vpp):
    """Reference Eq. 22 pipeline-time evaluation with interleaving.

    totals : f32[B, P]  per-stage time t_i + h_i (padded with zeros)
    mask   : f32[B, P]  1.0 for live stages
    k      : f32[B]     number of microbatches
    vpp    : f32[B]     interleaving degree (≥ 1)
    returns: f32[B]     K·max + (Σ − max)/vpp
    """
    masked = totals * mask
    s = masked.sum(axis=1)
    m = masked.max(axis=1)
    return k * m + (s - m) / vpp
