"""Layer-1 Pallas kernel: batched GBDT forest inference.

The paper's "XGBoost predicts η" step, as a data-parallel kernel. Trees use
the complete level-order layout (see ``gbdt_train.py``), so descent is
branch-free arithmetic — `idx ← 2·idx + 1 + (x[f] ≥ t)` — which vectorizes
across (rows × trees) with no divergence.

TPU adaptation (DESIGN.md §Hardware-Adaptation): rows are tiled along the
batch axis via ``BlockSpec`` so each grid step works on a ``BLOCK_ROWS``
slice resident in VMEM, while the (small) tree tables are replicated to
every grid step. Descent is gather + compare on the VPU; there is no matmul,
so the kernel is memory/VPU-bound by construction. ``interpret=True``
everywhere — the CPU PJRT plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 2048


def _forest_kernel(x_ref, feat_ref, thresh_ref, leaf_ref, o_ref, *, depth: int):
    # Descent is formulated with one-hot selects instead of gathers:
    # (a) it is the TPU-idiomatic form (iota+compare+reduce on the VPU, no
    #     scatter/gather units), and
    # (b) jax ≥ 0.8 lowers take_along_axis to gathers with operand batching
    #     dims that xla_extension 0.5.1 (the rust PJRT runtime) silently
    #     mis-executes — one-hot lowers to plain broadcast/compare/reduce,
    #     which round-trips the HLO text parser faithfully.
    # Level-local descent: at level j only the 2^j nodes of that level are
    # candidates, so the one-hot select runs over a width-2^j slice instead
    # of all 2^d−1 internal nodes — Σ_j 2^j = 2^d−1 total select work versus
    # depth·(2^d−1) for the naive formulation (≈5× at depth 5; §Perf).
    x = x_ref[...]  # [block, F]
    feat = feat_ref[...]  # [T, I] (int32)
    thresh = thresh_ref[...]  # [T, I]
    leaf = leaf_ref[...]  # [T, L]
    n = x.shape[0]
    n_features = x.shape[1]
    feat_iota = jnp.arange(n_features, dtype=jnp.int32)  # [F]

    # `local` is the index within the current level (level j has 2^j nodes
    # at global offset 2^j−1); after `depth` steps it IS the leaf index.
    local = jnp.zeros((n, feat.shape[0]), dtype=jnp.int32)
    for j in range(depth):
        width = 1 << j
        start = width - 1
        f_tab = feat[:, start : start + width]  # [T, w] (static slice)
        th_tab = thresh[:, start : start + width]
        level_iota = jnp.arange(width, dtype=jnp.int32)
        sel = (local[:, :, None] == level_iota[None, None, :]).astype(x.dtype)  # [n,T,w]
        f = (sel * f_tab[None, :, :].astype(x.dtype)).sum(axis=2)  # [n,T]
        # where-select (not multiply) — thresholds may be ±inf and 0·inf=NaN.
        th = jnp.where(sel > 0.5, th_tab[None, :, :], 0.0).sum(axis=2)  # [n,T]
        fsel = (f[:, :, None] == feat_iota[None, None, :].astype(x.dtype)).astype(x.dtype)
        xv = (fsel * x[:, None, :]).sum(axis=2)  # [n,T]
        local = 2 * local + (xv >= th).astype(jnp.int32)
    leaves = leaf.shape[1]
    leaf_iota = jnp.arange(leaves, dtype=jnp.int32)
    lsel = (local[:, :, None] == leaf_iota[None, None, :]).astype(x.dtype)  # [n,T,L]
    vals = (lsel * leaf[None, :, :]).sum(axis=2)
    o_ref[...] = vals.sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def forest_apply(x, feat, thresh, leaf, block_rows: int = BLOCK_ROWS):
    """Sum of tree outputs for each row (caller applies base + lr).

    x: f32[N, F]; feat/thresh: [T, I]; leaf: [T, L]; returns f32[N].
    N must be a multiple of ``block_rows`` or smaller than it (callers pad —
    the AOT scorer always presents a fixed batch).
    """
    import math

    n = x.shape[0]
    internal = feat.shape[1]
    depth = (internal + 1).bit_length() - 1
    # Largest tile ≤ block_rows that divides n exactly (shapes are static,
    # so this is resolved at trace time).
    block = math.gcd(n, block_rows)
    grid = (n // block,)
    kernel = functools.partial(_forest_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec(feat.shape, lambda i: (0, 0)),
            pl.BlockSpec(thresh.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaf.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, feat, thresh, leaf)


def forest_predict(x, feat, thresh, leaf, base: float, lr: float, block_rows: int = BLOCK_ROWS):
    """Full ensemble prediction: ``base + lr · Σ_t tree_t(x)``."""
    return base + lr * forest_apply(x, feat, thresh, leaf, block_rows=block_rows)
