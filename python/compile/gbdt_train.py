"""Gradient-boosted regression trees, trained with numpy (build-time only).

The paper uses XGBoost to predict the η efficiency factors; xgboost is not
available in this image, so we train our own ensemble with identical
semantics: squared loss, shrinkage, *complete* binary trees of fixed depth in
level order — the exact layout the rust inference (``gbdt/``) and the Pallas
kernel (``kernels/forest.py``) consume:

    internal nodes 0..2^d−1 : (feature, threshold)
    leaves         0..2^d   : value
    descent                 : idx ← 2·idx + 1 + (x[feat] ≥ thresh)

Degenerate nodes (empty/pure) use threshold = +inf so every row goes left and
both subtrees inherit the parent's fit.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

INF = np.float32(np.inf)


@dataclass
class Tree:
    depth: int
    feat: np.ndarray  # (2^d − 1,) int32
    thresh: np.ndarray  # (2^d − 1,) float32
    leaf: np.ndarray  # (2^d,) float32

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Vectorized branch-free descent over rows of ``x`` (n, f)."""
        idx = np.zeros(x.shape[0], dtype=np.int64)
        for _ in range(self.depth):
            f = self.feat[idx]
            t = self.thresh[idx]
            go_right = (x[np.arange(x.shape[0]), f] >= t).astype(np.int64)
            idx = 2 * idx + 1 + go_right
        return self.leaf[idx - (len(self.feat))]

    def to_json(self) -> dict:
        return {
            "depth": self.depth,
            "feat": [int(v) for v in self.feat],
            "thresh": [float(v) if np.isfinite(v) else 3.0e38 for v in self.thresh],
            "leaf": [float(v) for v in self.leaf],
        }


@dataclass
class Forest:
    trees: list[Tree]
    base: float
    lr: float
    n_features: int

    def predict(self, x: np.ndarray) -> np.ndarray:
        acc = np.zeros(x.shape[0], dtype=np.float64)
        for t in self.trees:
            acc += t.predict(x)
        return self.base + self.lr * acc

    def to_json(self) -> dict:
        return {
            "n_features": self.n_features,
            "base": float(self.base),
            "lr": float(self.lr),
            "trees": [t.to_json() for t in self.trees],
        }

    # Packed arrays for the Pallas kernel: feat (T, I) int32,
    # thresh (T, I) f32, leaf (T, L) f32 — all trees share one depth.
    # Degenerate +inf thresholds are clamped to the same large finite value
    # the JSON export uses, keeping kernel and rust inference bit-identical.
    def packed(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        feat = np.stack([t.feat for t in self.trees]).astype(np.int32)
        thresh = np.stack([t.thresh for t in self.trees]).astype(np.float32)
        thresh = np.where(np.isfinite(thresh), thresh, np.float32(3.0e38))
        leaf = np.stack([t.leaf for t in self.trees]).astype(np.float32)
        return feat, thresh, leaf


@dataclass
class TrainConfig:
    n_trees: int = 48
    depth: int = 5
    lr: float = 0.25
    n_thresholds: int = 24
    min_samples: int = 8
    seed: int = 0
    extra: dict = field(default_factory=dict)


def _fit_tree(x: np.ndarray, resid: np.ndarray, cfg: TrainConfig) -> Tree:
    """Greedy variance-reduction splits to a fixed depth (complete tree)."""
    n_internal = (1 << cfg.depth) - 1
    n_leaves = 1 << cfg.depth
    feat = np.zeros(n_internal, dtype=np.int32)
    thresh = np.full(n_internal, INF, dtype=np.float32)
    leaf = np.zeros(n_leaves, dtype=np.float32)

    # node id → row mask, breadth-first.
    masks: dict[int, np.ndarray] = {0: np.ones(x.shape[0], dtype=bool)}
    for node in range(n_internal):
        mask = masks.get(node)
        if mask is None or mask.sum() < cfg.min_samples:
            # Degenerate: all rows left; children inherit.
            masks[2 * node + 1] = mask if mask is not None else None
            masks[2 * node + 2] = None
            continue
        xs = x[mask]
        rs = resid[mask]
        best = (0.0, 0, INF)  # (gain, feature, threshold)
        total_sum = rs.sum()
        total_cnt = len(rs)
        base_sse_term = total_sum * total_sum / total_cnt
        for f in range(x.shape[1]):
            col = xs[:, f]
            qs = np.unique(
                np.quantile(col, np.linspace(0.05, 0.95, cfg.n_thresholds)).astype(np.float32)
            )
            for t in qs:
                right = col >= t
                nr = int(right.sum())
                nl = total_cnt - nr
                if nr == 0 or nl == 0:
                    continue
                sr = rs[right].sum()
                sl = total_sum - sr
                gain = sl * sl / nl + sr * sr / nr - base_sse_term
                if gain > best[0]:
                    best = (gain, f, t)
        _, bf, bt = best
        feat[node] = bf
        thresh[node] = bt
        go_right = x[:, bf] >= bt
        masks[2 * node + 1] = mask & ~go_right
        masks[2 * node + 2] = mask & go_right

    for li in range(n_leaves):
        mask = masks.get(n_internal + li)
        if mask is not None and mask.any():
            leaf[li] = resid[mask].mean()
    return Tree(cfg.depth, feat, thresh, leaf)


def train(x: np.ndarray, y: np.ndarray, cfg: TrainConfig | None = None) -> Forest:
    """Gradient boosting for squared loss: residual fitting with shrinkage."""
    cfg = cfg or TrainConfig()
    x = np.asarray(x, dtype=np.float32)
    y = np.asarray(y, dtype=np.float64)
    base = float(y.mean())
    pred = np.full_like(y, base)
    trees: list[Tree] = []
    for _ in range(cfg.n_trees):
        resid = y - pred
        tree = _fit_tree(x, resid, cfg)
        trees.append(tree)
        pred += cfg.lr * tree.predict(x)
    return Forest(trees, base, cfg.lr, x.shape[1])


def r2_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    ss_res = float(((y_true - y_pred) ** 2).sum())
    ss_tot = float(((y_true - y_true.mean()) ** 2).sum())
    return 1.0 - ss_res / max(ss_tot, 1e-12)
