"""Layer-2 JAX scorer graph — the batched cost model (paper §3.5).

Mirrors ``rust/src/cost/`` exactly (the HLO↔native parity test in
``rust/tests/integration_runtime.rs`` enforces agreement): per-stage operator
census → η factors via the Layer-1 GBDT forest kernel → per-stage times →
Eq. 22 pipeline composition via the Layer-1 pipeline kernel → step time.

Inputs (packed by ``rust/src/cost/features.rs`` — index constants below are
the same contract):

    stage_feats f32[B, PMAX, FS]
    stage_mask  f32[B, PMAX]
    strat_feats f32[B, FG]

Output: f32[B, 4] = [step_time, pipeline_time, dp_time, opt+offload_time].

The GBDT forests are *captured as constants* in the jitted graph, so the AOT
artifact is self-contained; retraining requires re-running ``make artifacts``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.forest import forest_apply
from .kernels.pipeline import pipeline_eval

# --- feature layout (mirror of rust/src/cost/features.rs) ---
FS = 29
FG = 8
PMAX = 64
OUT = 4

SF_PEAK_TFLOPS = 0
SF_HBM_GBS = 1
SF_UTIL_MAX = 2
SF_COMM_EFF_MAX = 3
SF_TP_BW_GBS = 4
SF_P2P_BW_GBS = 5
SF_LAYERS = 6
SF_IS_LAST = 7
SF_TP = 8
SF_MBS = 9
SF_SEQ = 10
SF_HIDDEN = 11
SF_FFN = 12
SF_KV_FRAC = 13
SF_HEADS = 14
SF_VOCAB = 15
SF_GATED = 16
SF_FLASH = 17
SF_RC_GRAN = 18
SF_RC_FRAC = 19
SF_TP_OVERLAP = 20
SF_P2P_OVERLAP = 21
SF_PARAMS_M = 22
SF_DP_BW_GBS = 23
SF_PCIE_GBS = 24
SF_N_EXPERTS = 25
SF_MOE_TOPK = 26
SF_EP = 27
SF_EP_BW_GBS = 28

GF_K = 0
GF_VPP = 1
GF_DP = 2
GF_OVERLAP_GRAD = 3
GF_OVERLAP_PARAM = 4
GF_DIST_OPT = 5
GF_OFFLOAD = 6
GF_SEQ_PARALLEL = 7

# --- composition constants (mirror of cost::CostConsts::default) ---
P2P_HIDE = 0.7
GRAD_REDUCE_HIDE = 0.8
PARAM_GATHER_HIDE = 0.8
TP_HIDE = 0.3
ADAM_BYTES_PER_PARAM = 20.0
HOST_DDR_GBS = 50.0
OFFLOAD_HIDE = 0.6

N_COMP_OPS = 6  # qkv, attn, out, up, down, head


def _log10(x):
    return jnp.log10(jnp.maximum(x, 1e-30))


def _comp_features(flops, min_dim, intensity, peak_tflops, hbm, util_max):
    """hw::comp_features over stacked op arrays (each [R])."""
    return jnp.stack(
        [
            _log10(jnp.maximum(flops, 1.0)),
            _log10(jnp.maximum(min_dim, 1.0)),
            _log10(jnp.maximum(intensity, 1e-3)),
            peak_tflops / 1000.0,
            hbm / 1000.0,
            util_max,
        ],
        axis=-1,
    )


def _comm_features(bytes_, bw_gbs, participants, comm_eff_max):
    return jnp.stack(
        [
            _log10(jnp.maximum(bytes_, 1.0)),
            _log10(jnp.maximum(bw_gbs, 1e-3)),
            _log10(jnp.maximum(participants, 1.0)),
            comm_eff_max,
        ],
        axis=-1,
    )


def build_scorer(comp_forest, comm_forest):
    """Return ``scorer(stage_feats, stage_mask, strat_feats) → f32[B, OUT]``.

    ``comp_forest``/``comm_forest`` are ``gbdt_train.Forest`` objects whose
    packed arrays are captured as jit constants.
    """
    comp_feat, comp_thresh, comp_leaf = comp_forest.packed()
    comm_feat, comm_thresh, comm_leaf = comm_forest.packed()
    comp_base, comp_lr = float(comp_forest.base), float(comp_forest.lr)
    comm_base, comm_lr = float(comm_forest.base), float(comm_forest.lr)

    comp_feat = jnp.asarray(comp_feat)
    comp_thresh = jnp.asarray(comp_thresh)
    comp_leaf = jnp.asarray(comp_leaf)
    comm_feat = jnp.asarray(comm_feat)
    comm_thresh = jnp.asarray(comm_thresh)
    comm_leaf = jnp.asarray(comm_leaf)

    def eta_comp(features):  # [R, 6] → [R] in (1e-4, 1]
        raw = comp_base + comp_lr * forest_apply(features, comp_feat, comp_thresh, comp_leaf)
        return jnp.clip(raw, 1e-4, 1.0)

    def eta_comm(features):  # [R, 4] → [R]
        raw = comm_base + comm_lr * forest_apply(features, comm_feat, comm_thresh, comm_leaf)
        return jnp.clip(raw, 1e-4, 1.0)

    def scorer(stage_feats, stage_mask, strat_feats):
        b, pmax, _ = stage_feats.shape
        rows = stage_feats.reshape(b * pmax, FS)  # [R, FS]

        peak_tf = rows[:, SF_PEAK_TFLOPS]
        peak = peak_tf * 1e12
        hbm = rows[:, SF_HBM_GBS]
        util = rows[:, SF_UTIL_MAX]
        ceff = rows[:, SF_COMM_EFF_MAX]
        tp_bw = rows[:, SF_TP_BW_GBS]
        p2p_bw = rows[:, SF_P2P_BW_GBS]
        layers = rows[:, SF_LAYERS]
        is_last = rows[:, SF_IS_LAST]
        tp = rows[:, SF_TP]
        mbs = rows[:, SF_MBS]
        seq = rows[:, SF_SEQ]
        h = rows[:, SF_HIDDEN]
        ffn = rows[:, SF_FFN]
        kvf = rows[:, SF_KV_FRAC]
        heads = rows[:, SF_HEADS]
        vocab = rows[:, SF_VOCAB]
        gated = rows[:, SF_GATED]
        flash = rows[:, SF_FLASH]
        rc_gran = rows[:, SF_RC_GRAN]
        rc_frac = rows[:, SF_RC_FRAC]
        tp_ovl = rows[:, SF_TP_OVERLAP]
        p2p_ovl = rows[:, SF_P2P_OVERLAP]
        params = rows[:, SF_PARAMS_M] * 1e6
        dp_bw = rows[:, SF_DP_BW_GBS]
        pcie = rows[:, SF_PCIE_GBS]
        n_experts = rows[:, SF_N_EXPERTS]
        moe_topk = rows[:, SF_MOE_TOPK]
        ep = rows[:, SF_EP]
        ep_bw = rows[:, SF_EP_BW_GBS]

        # Avoid 0/0 on padded rows (mask zeroes them out at the end).
        safe_tp = jnp.maximum(tp, 1.0)
        safe_heads = jnp.maximum(heads, 1.0)
        head_dim = h / safe_heads
        mb = mbs * seq
        gate = jnp.where(gated > 0.5, 2.0, 1.0)

        # --- operator census (mirror of cost::ops::stage_fwd_ops) ---
        def gemm(m_, n_, k_):
            flops = 2.0 * m_ * n_ * k_
            min_dim = jnp.minimum(jnp.minimum(m_, n_), k_)
            bytes_ = 2.0 * (m_ * k_ + k_ * n_ + m_ * n_)
            return flops, min_dim, bytes_

        one = jnp.ones_like(mb)
        # 1. qkv
        f1, d1, by1 = gemm(mb, (1.0 + 2.0 * kvf) * h / safe_tp, h)
        c1 = layers
        # 2. attention — flash (fused, count=layers) vs unfused (score and
        #    context have IDENTICAL shapes, so one class with count=2·layers
        #    — same total time, 1 fewer forest row per stage; §Perf L1-3).
        attn_flops = 2.0 * mbs * seq * seq * h / safe_tp
        fused_flops = 2.0 * attn_flops
        fused_bytes = 2.0 * 4.0 * mb * h / safe_tp
        unf_bytes = 2.0 * (mbs * safe_heads / safe_tp) * (
            2.0 * seq * head_dim + seq * seq
        )
        attn_dim = jnp.minimum(head_dim, seq)
        f2 = jnp.where(flash > 0.5, fused_flops, attn_flops)
        by2 = jnp.where(flash > 0.5, fused_bytes, unf_bytes)
        c2 = layers * jnp.where(flash > 0.5, 1.0, 2.0)
        # 3. out proj
        f4, d4, by4 = gemm(mb, h, h / safe_tp)
        c4 = layers
        # MoE: each token visits top-k experts (mirror of
        # ModelSpec::active_mlp_factor).
        mlp_passes = jnp.where(n_experts > 1.0, jnp.maximum(moe_topk, 1.0), 1.0)
        # 4. mlp up
        f5, d5, by5 = gemm(mb, gate * ffn / safe_tp, h)
        c5 = layers * mlp_passes
        # 5. mlp down
        f6, d6, by6 = gemm(mb, h, ffn / safe_tp)
        c6 = layers * mlp_passes
        # 6. lm head (last stage only)
        f7, d7, by7 = gemm(mb, vocab / safe_tp, h)
        c7 = is_last

        op_flops = jnp.stack([f1, f2, f4, f5, f6, f7], axis=0)  # [6, R]
        op_dims = jnp.stack([d1, attn_dim, d4, d5, d6, d7], axis=0)
        op_bytes = jnp.stack([by1, by2, by4, by5, by6, by7], axis=0)
        op_counts = jnp.stack([c1, c2, c4, c5, c6, c7], axis=0)
        op_intensity = op_flops / jnp.maximum(op_bytes, 1.0)

        r = b * pmax
        feats = _comp_features(
            op_flops.reshape(N_COMP_OPS * r),
            op_dims.reshape(N_COMP_OPS * r),
            op_intensity.reshape(N_COMP_OPS * r),
            jnp.tile(peak_tf, N_COMP_OPS),
            jnp.tile(hbm, N_COMP_OPS),
            jnp.tile(util, N_COMP_OPS),
        )
        etas = eta_comp(feats).reshape(N_COMP_OPS, r)
        op_times = op_counts * op_flops / (jnp.maximum(peak, 1.0)[None, :] * etas)
        fwd_comp = op_times.sum(axis=0)
        attn_fwd = op_times[1]

        # backward + recompute (mirror of cost::stage_time).
        bwd_comp = 2.0 * fwd_comp
        bwd_comp = bwd_comp + jnp.where(rc_gran == 2.0, rc_frac * fwd_comp, 0.0)
        bwd_comp = bwd_comp + jnp.where(
            (rc_gran == 1.0) & (flash < 0.5), attn_fwd, 0.0
        )

        # --- communication efficiencies (ONE fused forest launch) ---
        # The tp-collective, p2p and dp-gradient η_comm queries are stacked
        # into a single kernel call: pallas launch overhead dominates small
        # batches in interpret mode (§Perf iteration L1-2).
        dp = jnp.maximum(strat_feats[:, GF_DP], 1.0)
        dp_r = jnp.repeat(dp, pmax)
        act_bytes = 2.0 * mbs * seq * h
        grad_bytes = params * 2.0
        safe_ep = jnp.maximum(ep, 1.0)
        a2a_msg = act_bytes * jnp.maximum(moe_topk, 1.0) / safe_ep
        comm_feats = jnp.concatenate(
            [
                _comm_features(act_bytes, tp_bw, tp, ceff),
                _comm_features(act_bytes, p2p_bw, 2.0 * one, ceff),
                _comm_features(grad_bytes, dp_bw, dp_r, ceff),
                _comm_features(a2a_msg, ep_bw, ep, ceff),
            ],
            axis=0,
        )
        comm_etas = eta_comm(comm_feats)
        r_rows = act_bytes.shape[0]
        tp_eta = comm_etas[:r_rows]
        p2p_eta = comm_etas[r_rows : 2 * r_rows]
        dp_eta = comm_etas[2 * r_rows : 3 * r_rows]
        a2a_eta = comm_etas[3 * r_rows :]

        # --- MoE all-to-all (mirror of cost::stage_time a2a term) ---
        a2a_ring = layers * 2.0 * act_bytes * jnp.maximum(moe_topk, 1.0) * (safe_ep - 1.0) / safe_ep
        a2a_time = jnp.where(
            (n_experts > 1.0) & (ep > 1.0),
            a2a_ring / (jnp.maximum(ep_bw, 1e-3) * 1e9 * a2a_eta),
            0.0,
        )

        # --- TP collectives ---
        ring_per = 2.0 * act_bytes * (safe_tp - 1.0) / safe_tp
        n_tp_ops = 2.0 * layers + is_last
        tp_time = jnp.where(
            tp_bw > 0.0,
            n_tp_ops * ring_per / (jnp.maximum(tp_bw, 1e-3) * 1e9 * tp_eta),
            0.0,
        )
        tp_time = tp_time * jnp.where(tp_ovl > 0.5, 1.0 - TP_HIDE, 1.0)

        # --- p2p ---
        p2p_t = jnp.where(
            p2p_bw > 0.0,
            act_bytes / (jnp.maximum(p2p_bw, 1e-3) * 1e9 * p2p_eta),
            0.0,
        )
        p2p_t = p2p_t * jnp.where(p2p_ovl > 0.5, 1.0 - P2P_HIDE, 1.0)

        fwd_tot = (fwd_comp + tp_time + a2a_time + p2p_t).reshape(b, pmax)
        bwd_tot = (bwd_comp + tp_time + a2a_time + p2p_t).reshape(b, pmax)

        # --- pipeline composition (Layer-1 kernel, Eq. 22) ---
        k = strat_feats[:, GF_K]
        vpp = jnp.maximum(strat_feats[:, GF_VPP], 1.0)
        pipe_f = pipeline_eval(fwd_tot, stage_mask, k, vpp)
        pipe_b = pipeline_eval(bwd_tot, stage_mask, k, vpp)
        pipeline_time = pipe_f + pipe_b

        # --- DP communication (mirror of cost::dp_time) ---
        ovl_g = jnp.repeat(strat_feats[:, GF_OVERLAP_GRAD], pmax)
        ovl_p = jnp.repeat(strat_feats[:, GF_OVERLAP_PARAM], pmax)
        dist_opt = jnp.repeat(strat_feats[:, GF_DIST_OPT], pmax)
        ring = 2.0 * grad_bytes * (dp_r - 1.0) / dp_r
        t_dp = ring / (jnp.maximum(dp_bw, 1e-3) * 1e9 * dp_eta)
        t_dp = t_dp * jnp.where(ovl_g > 0.5, 1.0 - GRAD_REDUCE_HIDE, 1.0)
        ag = grad_bytes * (dp_r - 1.0) / dp_r
        t_ag = ag / (jnp.maximum(dp_bw, 1e-3) * 1e9 * dp_eta)
        t_ag = t_ag * jnp.where(ovl_p > 0.5, 1.0 - PARAM_GATHER_HIDE, 1.0)
        t_dp = t_dp + jnp.where(dist_opt > 0.5, t_ag, 0.0)
        t_dp = jnp.where(dp_r > 1.0, t_dp, 0.0)
        dp_time = (t_dp.reshape(b, pmax) * stage_mask).max(axis=1)

        # --- optimizer / offload (mirror of cost::optimizer_time) ---
        offload = jnp.repeat(strat_feats[:, GF_OFFLOAD], pmax)
        shard = params / jnp.where(dist_opt > 0.5, dp_r, 1.0)
        t_dev = shard * ADAM_BYTES_PER_PARAM / (jnp.maximum(hbm, 1e-3) * 1e9)
        transfer = shard * 6.0 / (jnp.maximum(pcie, 1e-3) * 1e9)
        host = shard * ADAM_BYTES_PER_PARAM / (HOST_DDR_GBS * 1e9)
        t_off = (transfer + host) * (1.0 - OFFLOAD_HIDE)
        t_opt = jnp.where(offload > 0.5, t_off, t_dev)
        extra = (t_opt.reshape(b, pmax) * stage_mask).max(axis=1)

        step = pipeline_time + dp_time + extra
        return jnp.stack([step, pipeline_time, dp_time, extra], axis=1)

    return scorer
