"""AOT compile path: train the η forests, lower the scorer, emit artifacts.

Run once via ``make artifacts`` (never on the search path):

    python -m compile.aot --out-dir ../artifacts

Emits:
    forest.json       — both GBDT ensembles (rust native engine + records)
    eff_samples.json  — noise-free hardware-truth samples (rust↔python
                        lockstep test ``crosscheck_hw.rs``)
    scorer.hlo.txt    — the Layer-2 scorer lowered to HLO *text*
    scorer_meta.json  — batch geometry + training metrics

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import effdata, gbdt_train
from .model import FG, FS, OUT, PMAX, build_scorer

DEFAULT_BATCH = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (gen_hlo.py recipe).

    ``print_large_constants=True`` is ESSENTIAL: the default printer elides
    multi-element constants as ``{...}``, which the rust-side HLO text
    parser silently materializes as zeros — the captured GBDT tables would
    vanish and every η prediction would collapse to the clamped base value.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def train_forests(profiles, fast: bool = False):
    """Fit the η_comp and η_comm ensembles on sampled hardware-truth data."""
    n_comp = 800 if fast else 4000
    n_comm = 600 if fast else 3000
    cfg_comp = gbdt_train.TrainConfig(
        n_trees=12 if fast else 48, depth=5, lr=0.3 if fast else 0.25
    )
    cfg_comm = gbdt_train.TrainConfig(
        n_trees=8 if fast else 32, depth=4, lr=0.35 if fast else 0.3
    )
    xs, ys = effdata.sample_comp_dataset(profiles, n_per_gpu=n_comp)
    comp = gbdt_train.train(xs, ys, cfg_comp)
    comp_r2 = gbdt_train.r2_score(ys, comp.predict(xs))
    xs2, ys2 = effdata.sample_comm_dataset(profiles, n_per_gpu=n_comm)
    comm = gbdt_train.train(xs2, ys2, cfg_comm)
    comm_r2 = gbdt_train.r2_score(ys2, comm.predict(xs2))
    return comp, comm, comp_r2, comm_r2


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument(
        "--fast", action="store_true", help="small forests/datasets (CI smoke)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    profiles = effdata.load_profiles()
    print(f"[aot] {len(profiles)} GPU profiles loaded")

    comp, comm, comp_r2, comm_r2 = train_forests(profiles, fast=args.fast)
    print(
        f"[aot] forests trained in {time.time() - t0:.1f}s — "
        f"η_comp R²={comp_r2:.4f} ({len(comp.trees)} trees), "
        f"η_comm R²={comm_r2:.4f} ({len(comm.trees)} trees)"
    )
    assert comp_r2 > 0.95, f"η_comp fit too weak: R²={comp_r2:.4f}"
    assert comm_r2 > 0.95, f"η_comm fit too weak: R²={comm_r2:.4f}"

    with open(os.path.join(args.out_dir, "forest.json"), "w") as f:
        json.dump({"comp": comp.to_json(), "comm": comm.to_json()}, f)
    with open(os.path.join(args.out_dir, "eff_samples.json"), "w") as f:
        json.dump(effdata.export_crosscheck_samples(profiles), f)

    # --- lower the scorer ---
    b = args.batch
    scorer = build_scorer(comp, comm)
    spec_sf = jax.ShapeDtypeStruct((b, PMAX, FS), jnp.float32)
    spec_mask = jax.ShapeDtypeStruct((b, PMAX), jnp.float32)
    spec_gf = jax.ShapeDtypeStruct((b, FG), jnp.float32)
    t1 = time.time()
    lowered = jax.jit(scorer).lower(spec_sf, spec_mask, spec_gf)
    hlo = to_hlo_text(lowered)
    print(f"[aot] scorer lowered in {time.time() - t1:.1f}s — {len(hlo)} chars of HLO")

    with open(os.path.join(args.out_dir, "scorer.hlo.txt"), "w") as f:
        f.write(hlo)
    meta = {
        "batch": b,
        "pmax": PMAX,
        "fs": FS,
        "fg": FG,
        "out": OUT,
        "comp_r2": comp_r2,
        "comm_r2": comm_r2,
        "comp_trees": len(comp.trees),
        "comm_trees": len(comm.trees),
        "fast": bool(args.fast),
    }
    with open(os.path.join(args.out_dir, "scorer_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] artifacts written to {args.out_dir} in {time.time() - t0:.1f}s total")

    # Smoke-execute the jitted scorer once so a broken lowering fails here,
    # not in the rust runtime.
    rng = np.random.default_rng(0)
    sf = jnp.asarray(rng.uniform(0.0, 1.0, (b, PMAX, FS)), dtype=jnp.float32)
    mask = jnp.zeros((b, PMAX), dtype=jnp.float32).at[:, :2].set(1.0)
    gf = jnp.ones((b, FG), dtype=jnp.float32)
    out = jax.jit(scorer)(sf, mask, gf)
    assert out.shape == (b, OUT), out.shape
    assert bool(jnp.isfinite(out).all()), "scorer produced non-finite output"
    print("[aot] smoke execution OK")


if __name__ == "__main__":
    main()
