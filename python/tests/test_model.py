"""Layer-2 scorer graph: shape, masking and physics sanity checks.

Full numeric parity with the rust cost model is enforced end-to-end by
``rust/tests/integration_runtime.rs`` (native vs HLO engines); here we check
the graph in isolation with small hand-built feature rows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import effdata, gbdt_train
from compile import model as scorer_model
from compile.model import (
    FG,
    FS,
    GF_DP,
    GF_DIST_OPT,
    GF_K,
    GF_OVERLAP_GRAD,
    GF_OVERLAP_PARAM,
    GF_VPP,
    PMAX,
    SF_COMM_EFF_MAX,
    SF_DP_BW_GBS,
    SF_FLASH,
    SF_FFN,
    SF_GATED,
    SF_HBM_GBS,
    SF_HEADS,
    SF_HIDDEN,
    SF_IS_LAST,
    SF_KV_FRAC,
    SF_LAYERS,
    SF_MBS,
    SF_P2P_BW_GBS,
    SF_P2P_OVERLAP,
    SF_PARAMS_M,
    SF_PCIE_GBS,
    SF_PEAK_TFLOPS,
    SF_RC_FRAC,
    SF_RC_GRAN,
    SF_SEQ,
    SF_TP,
    SF_TP_BW_GBS,
    SF_TP_OVERLAP,
    SF_UTIL_MAX,
    SF_VOCAB,
    build_scorer,
)


@pytest.fixture(scope="module")
def scorer():
    profiles = effdata.load_profiles()
    xs, ys = effdata.sample_comp_dataset(profiles, n_per_gpu=400)
    comp = gbdt_train.train(xs, ys, gbdt_train.TrainConfig(n_trees=10, depth=4))
    xs2, ys2 = effdata.sample_comm_dataset(profiles, n_per_gpu=300)
    comm = gbdt_train.train(xs2, ys2, gbdt_train.TrainConfig(n_trees=8, depth=4))
    return jax.jit(build_scorer(comp, comm))


def mk_stage_row(pp=4, stage=0, tp=2, mbs=1, layers=8, flash=1.0, h100=False):
    row = np.zeros(FS, dtype=np.float32)
    row[SF_PEAK_TFLOPS] = 989.0 if h100 else 312.0
    row[SF_HBM_GBS] = 3350.0 if h100 else 2039.0
    row[SF_UTIL_MAX] = 0.58 if h100 else 0.62
    row[SF_COMM_EFF_MAX] = 0.9 if h100 else 0.88
    row[SF_TP_BW_GBS] = 400.0 if tp > 1 else 0.0
    row[SF_P2P_BW_GBS] = 25.0 if stage < pp - 1 else 0.0
    row[SF_LAYERS] = layers
    row[SF_IS_LAST] = 1.0 if stage == pp - 1 else 0.0
    row[SF_TP] = tp
    row[SF_MBS] = mbs
    row[SF_SEQ] = 4096.0
    row[SF_HIDDEN] = 4096.0
    row[SF_FFN] = 11008.0
    row[SF_KV_FRAC] = 1.0
    row[SF_HEADS] = 32.0
    row[SF_VOCAB] = 32000.0
    row[SF_GATED] = 1.0
    row[SF_FLASH] = flash
    row[SF_RC_GRAN] = 0.0
    row[SF_RC_FRAC] = 0.0
    row[SF_TP_OVERLAP] = 1.0
    row[SF_P2P_OVERLAP] = 1.0
    row[SF_PARAMS_M] = 1000.0
    row[SF_DP_BW_GBS] = 25.0
    row[SF_PCIE_GBS] = 32.0
    return row


def mk_batch(b=4, pp=4, **kw):
    sf = np.zeros((b, PMAX, FS), dtype=np.float32)
    mask = np.zeros((b, PMAX), dtype=np.float32)
    gf = np.zeros((b, FG), dtype=np.float32)
    for bi in range(b):
        for st in range(pp):
            sf[bi, st] = mk_stage_row(pp=pp, stage=st, **kw)
            mask[bi, st] = 1.0
        gf[bi, GF_K] = 64.0
        gf[bi, GF_VPP] = 1.0
        gf[bi, GF_DP] = 8.0
        gf[bi, GF_OVERLAP_GRAD] = 1.0
        gf[bi, GF_OVERLAP_PARAM] = 1.0
        gf[bi, GF_DIST_OPT] = 1.0
    return jnp.asarray(sf), jnp.asarray(mask), jnp.asarray(gf)


class TestScorer:
    def test_output_shape_and_finite(self, scorer):
        sf, mask, gf = mk_batch()
        out = np.asarray(scorer(sf, mask, gf))
        assert out.shape == (4, 4)
        assert np.isfinite(out).all()
        assert (out[:, 0] > 0).all()
        # step = pipeline + dp + extra
        np.testing.assert_allclose(out[:, 0], out[:, 1:].sum(axis=1), rtol=1e-5)

    def test_padded_rows_are_harmless(self, scorer):
        """All-zero padded strategies must not produce NaN/Inf."""
        sf = jnp.zeros((4, PMAX, FS), dtype=jnp.float32)
        mask = jnp.zeros((4, PMAX), dtype=jnp.float32)
        gf = jnp.zeros((4, FG), dtype=jnp.float32).at[:, GF_K].set(1.0)
        gf = gf.at[:, GF_VPP].set(1.0).at[:, GF_DP].set(1.0)
        out = np.asarray(scorer(sf, mask, gf))
        assert np.isfinite(out).all()

    def test_h100_faster_than_a800(self, scorer):
        a = np.asarray(scorer(*mk_batch(h100=False)))[0, 0]
        h = np.asarray(scorer(*mk_batch(h100=True)))[0, 0]
        assert h < a

    def test_more_microbatches_longer_step(self, scorer):
        sf, mask, gf = mk_batch()
        gf2 = gf.at[:, GF_K].set(128.0)
        t1 = np.asarray(scorer(sf, mask, gf))[0, 0]
        t2 = np.asarray(scorer(sf, mask, gf2))[0, 0]
        assert t2 > 1.5 * t1

    def test_full_recompute_slower(self, scorer):
        sf, mask, gf = mk_batch()
        sf_rc = np.asarray(sf).copy()
        sf_rc[:, :, SF_RC_GRAN] = 2.0
        sf_rc[:, :, SF_RC_FRAC] = 1.0
        t0 = np.asarray(scorer(sf, mask, gf))[0, 0]
        t1 = np.asarray(scorer(jnp.asarray(sf_rc), mask, gf))[0, 0]
        assert t1 > t0

    def test_vpp_reduces_pipeline(self, scorer):
        sf, mask, gf = mk_batch(pp=8)
        gf_small_k = gf.at[:, GF_K].set(8.0)
        t1 = np.asarray(scorer(sf, mask, gf_small_k))[0, 1]
        gf_vpp = gf_small_k.at[:, GF_VPP].set(4.0)
        t2 = np.asarray(scorer(sf, mask, gf_vpp))[0, 1]
        assert t2 < t1

    def test_dp_time_zero_when_dp1(self, scorer):
        sf, mask, gf = mk_batch()
        gf1 = gf.at[:, GF_DP].set(1.0)
        out = np.asarray(scorer(sf, mask, gf1))
        assert np.allclose(out[:, 2], 0.0)

    def test_lowers_to_hlo_text(self, scorer):
        """The AOT path itself: lowering must produce parseable HLO text."""
        from compile.aot import to_hlo_text

        b = 8
        lowered = jax.jit(scorer.__wrapped__ if hasattr(scorer, "__wrapped__") else scorer).lower(
            jax.ShapeDtypeStruct((b, PMAX, FS), jnp.float32),
            jax.ShapeDtypeStruct((b, PMAX), jnp.float32),
            jax.ShapeDtypeStruct((b, FG), jnp.float32),
        )
        hlo = to_hlo_text(lowered)
        assert "ENTRY" in hlo
        assert f"f32[{b},{PMAX},{FS}]" in hlo.replace(" ", "")
