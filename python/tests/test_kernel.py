"""Layer-1 kernel correctness: Pallas vs pure-jnp oracle.

This is the CORE correctness signal of the compile path: the forest kernel
and the pipeline kernel must agree with ``kernels/ref.py`` across
hypothesis-driven shape/value sweeps.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.forest import forest_apply, forest_predict
from compile.kernels.pipeline import pipeline_eval
from compile.kernels.ref import forest_ref, pipeline_ref


def random_forest_arrays(rng, n_trees, depth, n_features):
    internal = (1 << depth) - 1
    leaves = 1 << depth
    feat = rng.integers(0, n_features, (n_trees, internal)).astype(np.int32)
    thresh = rng.uniform(-1.0, 1.0, (n_trees, internal)).astype(np.float32)
    leaf = rng.uniform(-2.0, 2.0, (n_trees, leaves)).astype(np.float32)
    return feat, thresh, leaf


class TestForestKernel:
    @pytest.mark.parametrize("depth", [1, 2, 4, 5])
    @pytest.mark.parametrize("n_trees", [1, 7, 48])
    def test_matches_ref(self, depth, n_trees):
        rng = np.random.default_rng(depth * 100 + n_trees)
        feat, thresh, leaf = random_forest_arrays(rng, n_trees, depth, 6)
        x = rng.uniform(-1.5, 1.5, (512, 6)).astype(np.float32)
        got = forest_apply(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thresh), jnp.asarray(leaf))
        want = forest_ref(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thresh), jnp.asarray(leaf))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)

    @given(
        n_rows_blocks=st.integers(1, 4),
        n_trees=st.integers(1, 16),
        depth=st.integers(1, 5),
        n_features=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_sweep(self, n_rows_blocks, n_trees, depth, n_features, seed):
        rng = np.random.default_rng(seed)
        feat, thresh, leaf = random_forest_arrays(rng, n_trees, depth, n_features)
        n = 64 * n_rows_blocks
        x = rng.uniform(-3.0, 3.0, (n, n_features)).astype(np.float32)
        got = forest_apply(
            jnp.asarray(x),
            jnp.asarray(feat),
            jnp.asarray(thresh),
            jnp.asarray(leaf),
            block_rows=64,
        )
        want = forest_ref(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thresh), jnp.asarray(leaf))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_matches_numpy_trainer(self):
        """Kernel agrees with the numpy Forest.predict used at train time."""
        from compile import gbdt_train

        rng = np.random.default_rng(3)
        x = rng.uniform(0.0, 1.0, (1024, 4)).astype(np.float32)
        y = (x[:, 0] * 2.0 + np.sin(3.0 * x[:, 1]) - x[:, 2] * x[:, 3]).astype(np.float32)
        forest = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=10, depth=4))
        feat, thresh, leaf = forest.packed()
        got = forest_predict(
            jnp.asarray(x),
            jnp.asarray(feat),
            jnp.asarray(thresh),
            jnp.asarray(leaf),
            forest.base,
            forest.lr,
        )
        want = forest.predict(x)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

    def test_boundary_thresholds(self):
        """x == threshold goes right (>= semantics, must match rust)."""
        feat = np.zeros((1, 1), dtype=np.int32)
        thresh = np.array([[0.5]], dtype=np.float32)
        leaf = np.array([[10.0, 20.0]], dtype=np.float32)
        x = np.array([[0.5], [0.4999]], dtype=np.float32)
        got = np.asarray(
            forest_apply(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thresh), jnp.asarray(leaf))
        )
        assert got[0] == 20.0  # equal → right
        assert got[1] == 10.0

    def test_infinite_threshold_goes_left(self):
        """Degenerate (pruned) nodes use +inf threshold → always left."""
        feat = np.zeros((1, 3), dtype=np.int32)
        thresh = np.array([[np.inf, np.inf, np.inf]], dtype=np.float32)
        leaf = np.array([[7.0, 1.0, 2.0, 3.0]], dtype=np.float32)
        x = np.array([[1e30]], dtype=np.float32)
        got = np.asarray(
            forest_apply(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thresh), jnp.asarray(leaf))
        )
        assert got[0] == 7.0


class TestPipelineKernel:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(1)
        totals = rng.uniform(0.0, 1.0, (256, 64)).astype(np.float32)
        mask = (rng.uniform(0, 1, (256, 64)) > 0.5).astype(np.float32)
        mask[:, 0] = 1.0  # at least one live stage
        k = rng.integers(1, 512, 256).astype(np.float32)
        vpp = rng.choice([1.0, 2.0, 4.0], 256).astype(np.float32)
        got = pipeline_eval(jnp.asarray(totals), jnp.asarray(mask), jnp.asarray(k), jnp.asarray(vpp))
        want = pipeline_ref(jnp.asarray(totals), jnp.asarray(mask), jnp.asarray(k), jnp.asarray(vpp))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    @given(
        b_blocks=st.integers(1, 3),
        p=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_sweep(self, b_blocks, p, seed):
        rng = np.random.default_rng(seed)
        b = 32 * b_blocks
        totals = rng.uniform(0.0, 2.0, (b, p)).astype(np.float32)
        mask = np.ones((b, p), dtype=np.float32)
        k = rng.integers(1, 100, b).astype(np.float32)
        vpp = rng.choice([1.0, 2.0, 4.0], b).astype(np.float32)
        got = pipeline_eval(
            jnp.asarray(totals), jnp.asarray(mask), jnp.asarray(k), jnp.asarray(vpp), block_b=32
        )
        want = pipeline_ref(jnp.asarray(totals), jnp.asarray(mask), jnp.asarray(k), jnp.asarray(vpp))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)

    def test_eq22_closed_form(self):
        """Homogeneous stages, vpp=1: Σ + (K−1)·max == K·t + (P−1)·t."""
        p, k, t = 8, 32.0, 0.01
        totals = np.full((1, p), t, dtype=np.float32)
        mask = np.ones((1, p), dtype=np.float32)
        got = float(
            pipeline_eval(
                jnp.asarray(totals),
                jnp.asarray(mask),
                jnp.asarray([k], dtype=np.float32),
                jnp.asarray([1.0], dtype=np.float32),
                block_b=1,
            )[0]
        )
        assert abs(got - (k * t + (p - 1) * t)) < 1e-6
