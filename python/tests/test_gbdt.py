"""GBDT training correctness and η-surface fit quality."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import effdata, gbdt_train


class TestTrainer:
    def test_fits_simple_function(self):
        rng = np.random.default_rng(0)
        x = rng.uniform(0, 1, (4000, 3)).astype(np.float32)
        y = 2.0 * x[:, 0] + np.where(x[:, 1] > 0.5, 1.0, -1.0) + 0.1 * x[:, 2]
        f = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=30, depth=4))
        r2 = gbdt_train.r2_score(y, f.predict(x))
        assert r2 > 0.97, f"R²={r2}"

    def test_constant_target(self):
        x = np.random.default_rng(1).uniform(0, 1, (200, 2)).astype(np.float32)
        y = np.full(200, 3.5)
        f = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=5, depth=3))
        np.testing.assert_allclose(f.predict(x), 3.5, atol=1e-6)

    def test_tree_shapes_complete(self):
        x = np.random.default_rng(2).uniform(0, 1, (500, 4)).astype(np.float32)
        y = x.sum(axis=1)
        f = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=3, depth=5))
        for t in f.trees:
            assert len(t.feat) == 31
            assert len(t.thresh) == 31
            assert len(t.leaf) == 32
            assert t.feat.max() < 4

    def test_json_serializable_and_finite(self):
        import json

        x = np.random.default_rng(3).uniform(0, 1, (300, 2)).astype(np.float32)
        y = x[:, 0] ** 2
        f = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=4, depth=3))
        s = json.dumps(f.to_json())
        back = json.loads(s)
        assert back["n_features"] == 2
        assert len(back["trees"]) == 4
        # inf thresholds encoded as a large finite float (rust JSON rejects inf)
        for t in back["trees"]:
            assert all(np.isfinite(v) for v in t["thresh"])

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_boosting_monotone_improvement(self, seed):
        """More trees never hurt training R² (squared-loss boosting)."""
        rng = np.random.default_rng(seed)
        x = rng.uniform(0, 1, (600, 3)).astype(np.float32)
        y = np.sin(4 * x[:, 0]) + x[:, 1] * x[:, 2]
        small = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=3, depth=3, seed=seed))
        big = gbdt_train.train(x, y, gbdt_train.TrainConfig(n_trees=20, depth=3, seed=seed))
        r2s = gbdt_train.r2_score(y, small.predict(x))
        r2b = gbdt_train.r2_score(y, big.predict(x))
        assert r2b >= r2s - 1e-9


class TestEtaFit:
    """The paper's >95% accuracy hinges on the η fit; verify it offline."""

    @pytest.fixture(scope="class")
    def profiles(self):
        return effdata.load_profiles()

    def test_comp_surface_fit(self, profiles):
        xs, ys = effdata.sample_comp_dataset(profiles, n_per_gpu=800)
        f = gbdt_train.train(xs, ys, gbdt_train.TrainConfig(n_trees=20, depth=5))
        r2 = gbdt_train.r2_score(ys, f.predict(xs))
        assert r2 > 0.95, f"η_comp R²={r2}"

    def test_comm_surface_fit(self, profiles):
        xs, ys = effdata.sample_comm_dataset(profiles, n_per_gpu=600)
        f = gbdt_train.train(xs, ys, gbdt_train.TrainConfig(n_trees=16, depth=4))
        r2 = gbdt_train.r2_score(ys, f.predict(xs))
        assert r2 > 0.95, f"η_comm R²={r2}"

    def test_eta_comp_properties(self, profiles):
        g = profiles[0]
        assert effdata.eta_comp(g, 1e12, 512, 200) > effdata.eta_comp(g, 1e6, 512, 200)
        assert effdata.eta_comp(g, 1e11, 16, 200) < effdata.eta_comp(g, 1e11, 512, 200)
        for f_ in (1e3, 1e9, 1e15):
            e = effdata.eta_comp(g, f_, 100, 50)
            assert 0.0 < e <= 1.0

    def test_eta_comm_properties(self, profiles):
        g = profiles[0]
        assert effdata.eta_comm(g, 1e9, 400, 8) > effdata.eta_comm(g, 1e4, 400, 8)
        assert effdata.eta_comm(g, 1e7, 400, 64) < effdata.eta_comm(g, 1e7, 400, 8)

    def test_profile_names_cover_paper(self, profiles):
        names = {g.name for g in profiles}
        assert {"a800", "h100", "h800", "a100"} <= names
