"""Rust/python lockstep checks for the price book (``data/price_book.json``).

The rust side pins the same file against ``PriceBook::builtin()`` in
``rust/src/pricing/mod.rs::tests::json_matches_builtin``; here we pin the
python loader's semantics and the cross-file contract with the hardware
profile: every cataloged GPU is priced, and the on-demand rate equals the
catalog's ``price_per_hour`` (so flat on-demand pricing reproduces the
pre-book cost numbers bit-for-bit).
"""

import pytest

from compile import effdata, pricing


def test_book_loads_and_validates():
    book = pricing.load_price_book()
    assert len(book.entries) > 0
    book.validate()
    names = [e.gpu for e in book.entries]
    assert names == sorted(names), "entries must be name-sorted"


def test_every_catalog_gpu_priced_at_catalog_rate():
    book = pricing.load_price_book()
    profiles = effdata.load_profiles()
    assert len(book.entries) == len(profiles)
    for p in profiles:
        e = book.get(p.name)
        assert e is not None, f"{p.name} missing from the price book"
        assert e.on_demand_per_hour == pytest.approx(p.price_per_hour, abs=0.0), (
            f"{p.name}: book on-demand {e.on_demand_per_hour} != "
            f"hw_profile price_per_hour {p.price_per_hour}"
        )
        assert e.spot_per_hour < e.on_demand_per_hour


def test_rate_semantics_match_rust():
    book = pricing.load_price_book()
    # Flat on-demand.
    assert book.rate_per_hour("a800") == pytest.approx(2.6)
    assert book.rate_per_second("a800") == pytest.approx(2.6 / 3600.0)
    # Spot billing.
    book.use_spot = True
    assert book.rate_per_hour("a800") == pytest.approx(1.04)
    book.use_spot = False
    # Time-of-day multiplier only applies when an hour is set.
    book.tod_multipliers[3] = 0.5
    assert book.rate_per_hour("a800") == pytest.approx(2.6)
    book.hour = 3
    assert book.rate_per_hour("a800") == pytest.approx(1.3)
    # Unknown GPUs miss (rust falls back to the catalog there).
    book.hour = None
    assert book.rate_per_hour("b200") is None


def test_validate_rejects_bad_books():
    book = pricing.load_price_book()
    book.tod_multipliers = book.tod_multipliers[:-1]
    with pytest.raises(ValueError):
        book.validate()

    book = pricing.load_price_book()
    book.hour = 24
    with pytest.raises(ValueError):
        book.validate()

    book = pricing.load_price_book()
    book.entries[0].spot_per_hour = book.entries[0].on_demand_per_hour * 2
    with pytest.raises(ValueError):
        book.validate()
