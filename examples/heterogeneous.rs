//! Mode-2: heterogeneous-GPU strategy search (paper §3.4).
//!
//! ```text
//! cargo run --release --example heterogeneous [-- --model llama2-13b --gpus 64 \
//!     --hetero a800:48,h100:48 --exhaustive]
//! ```
//!
//! Builds a mixed A800+H100 cluster, searches pipeline-segment partitions
//! (orderings × stage compositions × layer assignments, Eq. 23), and shows
//! how the winning assignment splits layers across GPU types compared with
//! the best expert plan.

use astra::cli::Cli;
use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::expert::ExpertPanel;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::{fmt_secs, Table};
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::GpuPoolMode;

fn main() -> astra::Result<()> {
    let args = Cli::new("heterogeneous", "mode-2 Astra search over mixed GPU types")
        .opt("model", "model name", Some("llama2-13b"))
        .opt("gpus", "total cluster GPUs", Some("64"))
        .opt("hetero", "caps 'type:n,type:n'", Some("a800:48,h100:48"))
        .flag("exhaustive", "exhaustive Eq.23 layer enumeration")
        .parse();

    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get(args.get("model").unwrap())?.clone();
    let total = args.get_usize("gpus")?;
    let caps = catalog.parse_caps(args.get("hetero").unwrap())?;

    println!(
        "Heterogeneous search: {} on {total} GPUs, caps {:?} (Eq. 2)",
        model.name,
        args.get("hetero").unwrap()
    );

    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { hetero_exhaustive: args.flag("exhaustive"), ..Default::default() },
    );
    let report = engine.search(&SearchRequest {
        mode: GpuPoolMode::Heterogeneous { total, caps: caps.clone() },
        model: model.clone(),
    })?;

    println!(
        "\n|S| = {} candidates, {} survived filters; search {} simulation {}",
        report.generated,
        report.scored,
        fmt_secs(report.search_secs),
        fmt_secs(report.simulate_secs)
    );

    let best = report.best().expect("no valid heterogeneous strategy");
    println!("\nAstra's plan: {}", best.summary());
    let mut t = Table::new(&["segment", "gpu", "stages", "layers/stage"]);
    for (i, seg) in best.strategy.cluster.segments.iter().enumerate() {
        t.row(&[
            i.to_string(),
            catalog.spec(seg.gpu).name.clone(),
            seg.stages.to_string(),
            seg.layers_per_stage.to_string(),
        ]);
    }
    t.emit("winning pipeline partition", None);

    // Compare with the expert panel on the simulator (Fig. 6's setup).
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let astra_tput = sim.measure(&model, &best.strategy).tokens_per_s;
    let panel = ExpertPanel::default();
    let mut t = Table::new(&["plan", "tokens/s (simulated)"]);
    t.row(&["astra".to_string(), format!("{astra_tput:.0}")]);
    let mut best_expert = 0.0f64;
    for (p, s) in panel.proposals_hetero(&model, &catalog, &caps, total) {
        let tput = sim.measure(&model, &s).tokens_per_s;
        best_expert = best_expert.max(tput);
        t.row(&[format!("expert:{}", p.name()), format!("{tput:.0}")]);
    }
    t.emit("Astra vs expert panel (Fig. 6 shape)", None);
    if best_expert > 0.0 {
        println!("speedup over best expert: {:.2}×", astra_tput / best_expert);
    }
    Ok(())
}
