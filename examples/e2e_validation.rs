//! END-TO-END validation driver — the repo's headline experiment.
//!
//! ```text
//! cargo run --release --example e2e_validation [-- --fast]
//! ```
//!
//! Exercises every layer on the paper's own workload grid and reports the
//! paper's headline metrics (recorded in EXPERIMENTS.md):
//!
//! 1. **Search** — full mode-1 searches for the seven paper models,
//!    through the real pipeline (generation → rule filter → memory filter
//!    → cost simulation), with the HLO engine (Layer-1 Pallas kernels via
//!    PJRT) when artifacts are present, native otherwise.
//! 2. **Accuracy** — the winning and top-k strategies are replayed on the
//!    discrete-event 1F1B simulator (the "cluster"); the paper claims >95%
//!    cost-model accuracy.
//! 3. **Expert comparison** — best-of-six-expert baselines vs Astra
//!    (Fig. 5's shape) on the simulator.
//! 4. **Headline timings** — search ≤ ~1.27 s, hetero e2e ≤ ~1.35 min.

use astra::cli::Cli;
use astra::coordinator::{AstraEngine, EngineConfig, ScoringEngine, SearchRequest};
use astra::expert::ExpertPanel;
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::{fmt_secs, Table};
use astra::simulator::{PipelineSimulator, SimConfig};
use astra::strategy::GpuPoolMode;

fn main() -> astra::Result<()> {
    let args = Cli::new("e2e_validation", "end-to-end Astra validation run")
        .flag("fast", "small grid (2 models, 1 GPU count)")
        .opt("gpus", "homogeneous GPU count", Some("64"))
        .opt("csv", "write summary CSV here", Some("e2e_summary.csv"))
        .parse();

    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let sim = PipelineSimulator::new(catalog.clone(), SimConfig::default());
    let panel = ExpertPanel::default();
    let count = args.get_usize("gpus")?;

    let engine_kind = if astra::runtime::artifacts_present() {
        println!("scoring engine: hlo (AOT Pallas scorer via PJRT)");
        ScoringEngine::Hlo
    } else {
        println!("scoring engine: native (run `make artifacts` for the HLO path)");
        ScoringEngine::Native
    };
    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { engine: engine_kind, ..Default::default() },
    );
    println!("hlo runtime active: {}", engine.hlo_active());

    let models: Vec<&str> = if args.flag("fast") {
        vec!["llama2-7b", "llama2-13b"]
    } else {
        vec!["llama2-7b", "llama2-13b", "llama2-70b", "llama3-8b", "llama3-70b", "glm-67b", "glm-130b"]
    };

    let mut t = Table::new(&[
        "model",
        "#strategies",
        "search",
        "simulation",
        "e2e",
        "best tokens/s",
        "accuracy",
        "vs expert",
    ]);
    let mut accs: Vec<f64> = Vec::new();
    let mut wins = 0usize;
    for name in &models {
        let model = registry.get(name)?.clone();
        let req = SearchRequest::homogeneous("a800", count, model.clone()).expect("request");
        let report = engine.search(&req)?;
        let best = report.best().expect("empty search");

        // Accuracy on the top-5 (prediction vs discrete-event measurement).
        let mut model_accs = Vec::new();
        for s in report.top.iter().take(5) {
            let r = sim.measure(&model, &s.strategy);
            model_accs.push(1.0 - (s.cost.step_time - r.step_time).abs() / r.step_time);
        }
        let acc = model_accs.iter().sum::<f64>() / model_accs.len() as f64;
        accs.push(acc);

        // Best-of-six experts on the simulator (Fig. 5).
        let astra_tput = sim.measure(&model, &best.strategy).tokens_per_s;
        let expert_tput = panel
            .proposals(&model, &catalog, catalog.find("a800")?, count)
            .iter()
            .map(|(_, s)| sim.measure(&model, s).tokens_per_s)
            .fold(0.0f64, f64::max);
        let ratio = if expert_tput > 0.0 { astra_tput / expert_tput } else { f64::NAN };
        if ratio >= 1.0 {
            wins += 1;
        }

        t.row(&[
            name.to_string(),
            report.generated.to_string(),
            fmt_secs(report.search_secs),
            fmt_secs(report.simulate_secs),
            fmt_secs(report.e2e_secs()),
            format!("{:.0}", best.cost.tokens_per_s),
            format!("{:.1}%", acc * 100.0),
            format!("{ratio:.2}×"),
        ]);
    }
    let csv = args.get("csv").map(std::path::PathBuf::from);
    t.emit(
        &format!("E2E validation — {count}×A800, mode-1 (cf. Table 1 / Fig. 5)"),
        csv.as_deref(),
    );

    // Heterogeneous headline (mode 2): one full search, timed.
    let model = registry.get("llama2-13b")?.clone();
    let caps = vec![(catalog.find("a800")?, count * 3 / 4), (catalog.find("h100")?, count * 3 / 4)];
    let t0 = std::time::Instant::now();
    let hrep = engine.search(&SearchRequest {
        mode: GpuPoolMode::Heterogeneous { total: count, caps },
        model: model.clone(),
    })?;
    let hetero_secs = t0.elapsed().as_secs_f64();
    let hbest = hrep.best().expect("hetero search empty");
    let hacc = {
        let r = sim.measure(&model, &hbest.strategy);
        1.0 - (hbest.cost.step_time - r.step_time).abs() / r.step_time
    };

    let mean_acc = accs.iter().sum::<f64>() / accs.len() as f64;
    println!("\n=== headline metrics (paper §1 / abstract) ===");
    println!("mean cost-model accuracy (top-5 × {} models): {:.2}% (paper: >95%)", models.len(), mean_acc * 100.0);
    println!("Astra ≥ expert in {wins}/{} settings (paper: matches or exceeds)", models.len());
    println!(
        "hetero e2e: {} — {} candidates (paper: ≤1.35 min); accuracy {:.1}%",
        fmt_secs(hetero_secs),
        hrep.generated,
        hacc * 100.0
    );
    assert!(mean_acc > 0.95, "accuracy headline violated: {:.3}", mean_acc);
    assert!(hetero_secs < 120.0, "hetero search exceeded 2 minutes");
    println!("\nE2E VALIDATION PASSED");
    Ok(())
}
