//! Quickstart: mode-1 (homogeneous) strategy search.
//!
//! ```text
//! cargo run --release --example quickstart [-- --model llama2-7b --gpu a800 --gpus 64]
//! ```
//!
//! Searches the full Megatron parameter space for Llama-2-7B on 64×A800,
//! prints the Table-1-style phase accounting and the five best strategies,
//! then replays the winner on the discrete-event simulator to show the
//! predicted-vs-measured agreement.

use astra::cli::Cli;
use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::report::{fmt_secs, Table};
use astra::simulator::{PipelineSimulator, SimConfig};

fn main() -> astra::Result<()> {
    let args = Cli::new("quickstart", "homogeneous Astra search")
        .opt("model", "model name", Some("llama2-7b"))
        .opt("gpu", "GPU type", Some("a800"))
        .opt("gpus", "GPU count", Some("64"))
        .parse();

    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get(args.get("model").unwrap())?.clone();
    let count = args.get_usize("gpus")?;

    println!(
        "Searching strategies for {} on {}×{} (gbs={} seq={})",
        model.name,
        count,
        args.get("gpu").unwrap(),
        model.global_batch,
        model.seq_len
    );

    let engine = AstraEngine::new(catalog.clone(), EngineConfig::default());
    let req = SearchRequest::homogeneous(args.get("gpu").unwrap(), count, model.clone())?;
    let report = engine.search(&req)?;

    println!(
        "\n|S| = {} generated → {} rule-filtered, {} memory-filtered, {} simulated",
        report.generated, report.rule_filtered, report.mem_filtered, report.scored
    );
    println!(
        "search {} + simulation {} = e2e {}",
        fmt_secs(report.search_secs),
        fmt_secs(report.simulate_secs),
        fmt_secs(report.e2e_secs())
    );

    let mut t = Table::new(&["#", "strategy", "step", "tokens/s", "MFU"]);
    for (i, s) in report.top.iter().take(5).enumerate() {
        t.row(&[
            (i + 1).to_string(),
            s.strategy.summary(),
            fmt_secs(s.cost.step_time),
            format!("{:.0}", s.cost.tokens_per_s),
            format!("{:.3}", s.cost.mfu),
        ]);
    }
    t.emit("top strategies", None);

    // Replay the winner on the ground-truth simulator.
    let best = report.best().expect("no strategy survived");
    let sim = PipelineSimulator::new(catalog, SimConfig::default());
    let measured = sim.measure(&model, &best.strategy);
    let acc = 1.0 - (best.cost.step_time - measured.step_time).abs() / measured.step_time;
    println!(
        "\nwinner replayed on the discrete-event simulator:\n  predicted {}  measured {}  accuracy {:.1}%",
        fmt_secs(best.cost.step_time),
        fmt_secs(measured.step_time),
        acc * 100.0
    );
    Ok(())
}
