//! Mode-3: money-limit search (paper §3.6 / Fig. 7), homogeneous or mixed.
//!
//! ```text
//! cargo run --release --example cost_optimizer [-- --model llama2-7b --gpu h100 \
//!     --max-gpus 256 --budget 4000 --train-tokens 1e9]
//! cargo run --release --example cost_optimizer -- --hetero a800:32,h100:16 \
//!     --budget 4000 --spot
//! ```
//!
//! Without `--hetero`: sweeps GPU counts of one type (Eq. 3). With
//! `--hetero 'type:cap,…'`: the heterogeneous money search — mixed-type
//! pool sizes are swept under the per-type caps, every candidate is priced
//! per type per hour through the price book (`--spot` bills spot rates),
//! and a branch-and-bound pruner drops pools that cannot fit the budget.
//! Either way the Pareto-optimal pool (throughput vs USD — the paper's
//! "optimal line") is printed and the fastest plan under the money ceiling
//! selected.

use astra::cli::Cli;
use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::MoneyModel;
use astra::pricing::PriceBook;
use astra::report::Table;
use astra::strategy::GpuPoolMode;

fn main() -> astra::Result<()> {
    let args = Cli::new("cost_optimizer", "mode-3 money-limited Astra search")
        .opt("model", "model name", Some("llama2-7b"))
        .opt("gpu", "GPU type (homogeneous sweep)", Some("h100"))
        .opt("max-gpus", "maximum cluster size", Some("256"))
        .opt("budget", "money ceiling in USD", Some("4000"))
        .opt("train-tokens", "token budget being priced", Some("1e9"))
        .opt("hetero", "mixed-pool caps 'type:cap,type:cap' (hetero-cost mode)", None)
        .flag("spot", "bill at spot rates instead of on-demand")
        .parse();

    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get(args.get("model").unwrap())?.clone();
    let budget = args.get_f64("budget")?;
    let train_tokens = args.get_f64("train-tokens")?;

    let mut book = PriceBook::builtin();
    book.use_spot = args.flag("spot");
    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { money: MoneyModel { train_tokens, book }, ..Default::default() },
    );

    let mode = match args.get("hetero") {
        Some(spec) => {
            let caps = catalog.parse_caps(spec)?;
            println!(
                "Pricing a {:.1e}-token training of {} on mixed pools (caps {spec}, {}), budget ${budget:.0}",
                train_tokens,
                model.name,
                if args.flag("spot") { "spot rates" } else { "on-demand rates" },
            );
            GpuPoolMode::HeteroCost { caps, max_money: budget }
        }
        None => {
            let gpu = catalog.find(args.get("gpu").unwrap())?;
            println!(
                "Pricing a {:.1e}-token training of {} on up to {}×{} (${:.2}/h each), budget ${budget:.0}",
                train_tokens,
                model.name,
                args.get_usize("max-gpus")?,
                catalog.spec(gpu).name,
                catalog.spec(gpu).price_per_hour
            );
            GpuPoolMode::Cost {
                gpu,
                max_count: args.get_usize("max-gpus")?,
                max_money: budget,
            }
        }
    };

    let report = engine.search(&SearchRequest { mode, model: model.clone() })?;

    println!(
        "\n{} candidates scored; frontier size {}; {} pools pruned",
        report.scored,
        report.pool.len(),
        report.pruned_pools
    );

    // The Fig. 7 "optimal line": throughput vs money along the frontier.
    let mut t = Table::new(&["tokens/s", "run cost USD", "within budget"]);
    for e in report.pool.entries() {
        t.row(&[
            format!("{:.0}", e.throughput),
            format!("{:.0}", e.cost),
            if e.cost <= budget { "yes".into() } else { String::new() },
        ]);
    }
    t.emit("Pareto-optimal pool (Fig. 7 'optimal line')", None);

    match report.pool.best_within_budget(budget) {
        Some(pick) => {
            println!(
                "\nselected: {:.0} tokens/s for ${:.0} (≤ ${budget:.0})",
                pick.throughput, pick.cost
            );
            let wall = train_tokens / pick.throughput / 3600.0;
            println!("estimated wall-clock: {wall:.1} h");
        }
        None => println!("\nno strategy fits the ${budget:.0} budget — raise it or shrink the run"),
    }
    Ok(())
}
