//! Mode-3: money-limit search (paper §3.6 / Fig. 7).
//!
//! ```text
//! cargo run --release --example cost_optimizer [-- --model llama2-7b --gpu h100 \
//!     --max-gpus 256 --budget 4000 --train-tokens 1e9]
//! ```
//!
//! Sweeps GPU counts (Eq. 3), prices every surviving strategy for a token
//! budget, prints the Pareto-optimal pool (throughput vs USD — the paper's
//! "optimal line"), and selects the fastest plan under the money ceiling.

use astra::cli::Cli;
use astra::coordinator::{AstraEngine, EngineConfig, SearchRequest};
use astra::gpu::GpuCatalog;
use astra::model::ModelRegistry;
use astra::pareto::MoneyModel;
use astra::report::Table;
use astra::strategy::GpuPoolMode;

fn main() -> astra::Result<()> {
    let args = Cli::new("cost_optimizer", "mode-3 money-limited Astra search")
        .opt("model", "model name", Some("llama2-7b"))
        .opt("gpu", "GPU type", Some("h100"))
        .opt("max-gpus", "maximum cluster size", Some("256"))
        .opt("budget", "money ceiling in USD", Some("4000"))
        .opt("train-tokens", "token budget being priced", Some("1e9"))
        .parse();

    let catalog = GpuCatalog::builtin();
    let registry = ModelRegistry::builtin();
    let model = registry.get(args.get("model").unwrap())?.clone();
    let gpu = catalog.find(args.get("gpu").unwrap())?;
    let max_count = args.get_usize("max-gpus")?;
    let budget = args.get_f64("budget")?;
    let train_tokens = args.get_f64("train-tokens")?;

    println!(
        "Pricing a {:.1e}-token training of {} on up to {max_count}×{} (${:.2}/h each), budget ${budget:.0}",
        train_tokens,
        model.name,
        catalog.spec(gpu).name,
        catalog.spec(gpu).price_per_hour
    );

    let engine = AstraEngine::new(
        catalog.clone(),
        EngineConfig { money: MoneyModel { train_tokens }, ..Default::default() },
    );
    let report = engine.search(&SearchRequest {
        mode: GpuPoolMode::Cost { gpu, max_count, max_money: budget },
        model: model.clone(),
    })?;

    println!(
        "\nswept counts 2..{max_count}; {} candidates scored; frontier size {}",
        report.scored,
        report.pool.len()
    );

    // The Fig. 7 "optimal line": throughput vs money along the frontier.
    let mut t = Table::new(&["tokens/s", "run cost USD", "within budget"]);
    for e in report.pool.entries() {
        t.row(&[
            format!("{:.0}", e.throughput),
            format!("{:.0}", e.cost),
            if e.cost <= budget { "yes".into() } else { String::new() },
        ]);
    }
    t.emit("Pareto-optimal pool (Fig. 7 'optimal line')", None);

    match report.pool.best_within_budget(budget) {
        Some(pick) => {
            println!(
                "\nselected: {:.0} tokens/s for ${:.0} (≤ ${budget:.0})",
                pick.throughput, pick.cost
            );
            let wall = train_tokens / pick.throughput / 3600.0;
            println!("estimated wall-clock: {wall:.1} h");
        }
        None => println!("\nno strategy fits the ${budget:.0} budget — raise it or shrink the run"),
    }
    Ok(())
}
